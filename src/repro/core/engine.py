"""DistributedSearchEngine — the paper's methods at pod scale.

The collection is range-sharded over the mesh's data-parallel axes; each
shard owns a FrozenIndex over its rows (ids stay global) plus the GLOBAL
distance histogram and global N, so per-shard r_delta matches the
single-node semantics. A query batch is replicated to all shards, each
runs the batched Algorithm 2 locally (shard_map), and per-shard top-k
rows are merged with an all-gather + static sort.

Guarantee preservation under sharding (docs/PERF.md §6): every global true
r-th NN lives in some shard where it ranks <= r locally; the local
guarantee bounds that shard's reported r-th by (1+eps) x local true r-th
<= (1+eps) x global true r-th, and the merged r-th best across shards
only improves — so exact/epsilon/delta-epsilon transfer. For delta<1 the
per-shard stopping radius uses the global N, making each shard's early
stop conservative w.r.t. the global distribution.

Fault tolerance: the frozen artifact checkpoints via train/checkpoint.py
like any pytree; straggler mitigation degrades the guarantee to
ng(nprobe) under a deadline — the taxonomy is the mitigation (paper
Fig. 8 shows the first bsf is already near-exact). Since PR 8 the
out-of-core path is fault-tolerant end to end (docs/FAULT.md): shards
are served by CONCURRENT owners (a worker pool streaming results into
the topk_merge_unique fold as they land — the merge is a commutative
(d, id)-lex selection, so completion order cannot change the answer),
``build(replicas=R)`` persists R copies of every shard store with
round-robin owner assignment, a failed/timed-out attempt retries with
capped exponential backoff and fails over to the next copy
(serve/fault.py: RetryPolicy + CircuitBreaker), and a shard lost past
every copy degrades the answer honestly — the query completes over
the surviving shards and OocStats reports ``degraded`` /
``shards_lost`` / ``effective_delta`` with delta recomputed from the
global distance histogram mass the missing rows own
(core.guarantees.effective_delta_after_loss).
"""

from __future__ import annotations

import dataclasses
import os
import shutil
import tempfile
import threading
import warnings
from concurrent.futures import ThreadPoolExecutor, as_completed
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat, obs
from repro.kernels import ops
from repro.obs import OocStats

from .guarantees import Guarantee, joint_n_total
from .histogram import DistanceHistogram, build_histogram
from .index import FrozenIndex
from .indexes import dstree, isax, vafile
from .search import SearchResult, search_impl
from .spec import (IndexSpec, StoreSpec, coerce_build_args,
                   coerce_store_spec)


class QueryResult(NamedTuple):
    """What :meth:`DistributedEngine.query` returns: the SearchResult
    fields plus the per-query :class:`OocStats` traveling WITH the
    answer. Stats used to be published through the mutable
    ``engine.last_ooc_stats`` field, which misattributes them the
    moment two ``query()`` calls run concurrently (the continuous-
    batching serving front has one in flight per lane) — so the field
    is gone and the ``engine-stats`` analysis rule keeps it gone
    (docs/ANALYSIS.md). ``stats`` is None on the resident shard_map
    path (no I/O to account) and an aggregated OocStats on the
    out-of-core path (per-shard schemas under ``.stats.shards``,
    degradation triple when shards were lost — docs/FAULT.md)."""

    dists: jax.Array           # [B, k] Euclidean distances, ascending
    ids: jax.Array             # [B, k] global row ids (-1 = missing)
    leaves_visited: jax.Array  # [B] int32, summed over shards
    rows_scanned: jax.Array    # [B] int32, summed over shards
    lb_computed: jax.Array     # scalar int32
    stats: Optional[OocStats] = None

class EngineSegment(NamedTuple):
    """One compacted delta segment (docs/INGEST.md): the leaf-
    contiguous on-disk artifact the background compactor froze out of
    the delta tier — codec-aware through the ordinary ``save_index``
    path, served exactly like one more shard. ``born_seq`` is the
    delta sequence the freeze happened at: any kill with a NEWER
    sequence masks this segment's copy of the id (store.delta kill
    rule), which is what makes publishing safe while deletes race the
    build. ``index`` keeps the pre-encode f32-resident FrozenIndex on
    resident engines so segment scoring matches the resident base
    arithmetic; out-of-core engines serve the segment from its store
    dir (codec-faithful) instead."""
    dir: str
    born_seq: int
    n_rows: int
    ids_np: np.ndarray                  # [npad] global ids (-1 pad)
    index: Optional[FrozenIndex] = None


class _MutView(NamedTuple):
    """Everything one query needs to serve a mutable-tier snapshot
    jointly with the frozen base (docs/INGEST.md): the snapshot
    itself, the joint r_delta row count
    (core.guarantees.joint_n_total — inserts RAISE N, deletes never
    lower it), and each published segment's tombstone mask under this
    snapshot's kills. Computed once per query, immutable afterwards."""
    snap: object                         # store.delta.DeltaSnapshot
    joint_n: int
    seg_dead: Tuple[np.ndarray, ...]     # per segment, [npad] bool


_BUILDERS = {
    "isax2+": isax.build,
    "dstree": dstree.build,
    "va+file": vafile.build,
}


def _pad_to(arr: np.ndarray, target: int, fill) -> np.ndarray:
    if arr.shape[0] == target:
        return arr
    pad = np.full((target - arr.shape[0],) + arr.shape[1:], fill,
                  arr.dtype)
    return np.concatenate([arr, pad], axis=0)


def _discover_replicas(spill_dir: str, shard_dirs: Tuple[str, ...]
                       ) -> Tuple[Tuple[str, ...], ...]:
    """Per shard: (primary, *replica copies) found on disk. Replicas
    live under spill_dir/replicas/rN/shard_NNNN — deliberately NOT
    top-level shard_* names, which open_spill would mis-discover as
    independent shards."""
    rep_root = os.path.join(spill_dir, "replicas")
    rdirs = sorted(os.listdir(rep_root)) \
        if os.path.isdir(rep_root) else []
    out = []
    for d in shard_dirs:
        name = os.path.basename(d)
        copies = [d]
        for rd in rdirs:
            cand = os.path.join(rep_root, rd, name)
            if os.path.isdir(cand):
                copies.append(cand)
        out.append(tuple(copies))
    return tuple(out)


@dataclasses.dataclass
class DistributedEngine:
    mesh: Optional[Mesh]  # None for an OOC-only engine (open_spill)
    axes: Tuple[str, ...] = ("data",)
    method: str = "dstree"
    stacked: Optional[FrozenIndex] = None  # leading shard axis on arrays
    shard_dirs: Optional[Tuple[str, ...]] = None  # spilled store dirs
    # explicit shard count for a MESH-FREE engine (mesh=None +
    # build(keep_resident=False): multi-shard OOC serving without any
    # device mesh — the single-process stand-in for per-host shard
    # ownership); ignored when a mesh is set
    shards: Optional[int] = None
    # per shard: every on-disk copy of its store, PRIMARY FIRST
    # (build(replicas=R) / open_spill discovery); the failover loop
    # rotates the attempt order per shard for round-robin ownership
    shard_replica_dirs: Optional[Tuple[Tuple[str, ...], ...]] = None
    # the typed build/open surface (core/spec.py): what was built and
    # how it is served — including the delta/compaction knobs
    index_spec: Optional[IndexSpec] = None
    store_spec: Optional[StoreSpec] = None
    # ---- mutable tier (docs/INGEST.md), armed by enable_writes() ----
    _delta: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)
    # serializes enable_writes/segment-numbering bookkeeping (the
    # delta tier itself carries its own lock; lock order: _write_lock
    # is a leaf, never held across delta or store calls)
    _write_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)
    _seg_dir: Optional[str] = dataclasses.field(
        default=None, repr=False, compare=False)
    _seg_seq: int = dataclasses.field(
        default=0, repr=False, compare=False)
    _compactor: Optional[threading.Thread] = dataclasses.field(
        default=None, repr=False, compare=False)
    _compactor_stop: Optional[threading.Event] = dataclasses.field(
        default=None, repr=False, compare=False)
    # per-shard host copies of the stacked id arrays (resident
    # engines): tombstone masks are recomputed from these when the
    # kill set advances, without pulling device arrays per query
    _shard_ids_host: Optional[list] = dataclasses.field(
        default=None, repr=False, compare=False)
    # frozen-unit dead-mask cache keyed by unit, valued
    # (kills_version, mask). Lock-free like _query_fns: dict get/set
    # are GIL-atomic and racing snapshots recompute from their own
    # consistent kill copies
    _dead_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    # (kills_version, device [S, max_rows] bool) stacked tombstones
    # for the resident shard_map operand
    _dead_stacked: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False)
    # jitted query fns keyed by (k, guarantee, batch shape, ...): the
    # shard_map body closes over those values, so a fresh closure per
    # call would defeat jit's compile cache. Lock-free on purpose:
    # dict get/set are GIL-atomic and two threads racing to build the
    # same key produce interchangeable callables (last one wins)
    _query_fns: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    # out-of-core serving state: per-shard LeafStore handles + warm
    # device leaf caches, opened lazily on the first OOC query and
    # reused across queries (the serving regime)
    _stores: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    _shard_caches: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    # serializes _stores/_shard_caches mutation against concurrent
    # shard owners and close(); per-shard search runs OUTSIDE it
    _ooc_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)
    # per shard-store-copy serving locks: CONCURRENT query() calls
    # (one per serving lane) share the warm per-copy DeviceLeafCache,
    # whose slot pool is only consistent for one query at a time (a
    # second query's get_slots may evict a slot the first is about to
    # gather) — so one query's use of one copy is one critical
    # section. Distinct shards/copies still serve fully in parallel;
    # lock order is copy lock -> _ooc_lock -> cache._lock (acyclic,
    # asserted by the lockorder stress test)
    _copy_locks: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    # persistent per-(shard, copy) circuit breaker (serve/fault.py),
    # created lazily on the first fault-tolerant OOC query
    _breaker: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def n_shards(self) -> int:
        if self.mesh is None:
            if self.shards is not None:
                return int(self.shards)
            return len(self.shard_dirs) if self.shard_dirs else 1
        shape = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        out = 1
        for a in self.axes:
            out *= shape[a]
        return out

    @classmethod
    def open_spill(cls, store, *, mesh: Optional[Mesh] = None,
                   axes: Tuple[str, ...] = ("data",),
                   index: Optional[IndexSpec] = None,
                   method: Optional[str] = None) -> "DistributedEngine":
        """Open an engine over an existing spilled build artifact
        WITHOUT loading any shard into HBM — the serving path for
        collections larger than device memory (multi-host: each host
        opens the shards it owns). ``store`` is a
        :class:`~repro.core.spec.StoreSpec` (its ``spill_dir`` names
        the artifact; its delta/compaction knobs govern
        :meth:`enable_writes`); a bare spill-dir string and the old
        ``method=`` kwarg keep working for one release via the
        APIDeprecationWarning shim (core/spec.py). ``query``
        auto-detects the missing resident index and serves
        out-of-core. Replica copies persisted by ``build`` with
        ``StoreSpec(replicas=R)`` (spill_dir/replicas/rN/shard_NNNN)
        are discovered too and arm failover."""
        ispec, sspec = coerce_store_spec(store, method=method,
                                         index=index)
        spill_dir = sspec.spill_dir
        shard_dirs = tuple(sorted(
            os.path.join(spill_dir, d) for d in os.listdir(spill_dir)
            if d.startswith("shard_")))
        if not shard_dirs:
            raise ValueError(f"no shard_* stores under {spill_dir!r}")
        eng = cls(mesh=mesh, axes=tuple(axes), method=ispec.method)
        eng.index_spec = ispec
        eng.store_spec = sspec
        eng.shard_dirs = shard_dirs
        eng.shard_replica_dirs = _discover_replicas(spill_dir,
                                                    shard_dirs)
        return eng

    # ------------------------------------------------------------------
    def build(self, data: np.ndarray, key=None, *,
              index: Optional[IndexSpec] = None,
              store: Optional[StoreSpec] = None, **legacy):
        """Shard rows, build per-shard indexes (embarrassingly parallel
        on hosts), stack and device_put with the shard axis mapped onto
        the mesh axes.

        The configuration surface is two typed specs (core/spec.py):
        ``index=IndexSpec(method, params)`` says WHAT to build (method
        + builder params such as ``leaf_cap``); ``store=StoreSpec(...)``
        says WHERE/HOW to serve it. The old loose spelling —
        ``build(spill_dir=..., codec=..., keep_resident=...,
        replicas=..., **builder_params)`` — keeps working for one
        release via the APIDeprecationWarning shim.

        ``StoreSpec.spill_dir`` additionally persists every shard as an
        on-disk store artifact (spill_dir/shard_NNNN, global ids and
        global n_total preserved) so shards can be served out-of-core —
        since PR 4 directly by :meth:`query` (auto-detected, or forced
        with ``ooc=True``), the path toward collections larger than pod
        HBM. ``StoreSpec.codec`` selects each shard's leaf payload
        encoding ("f32"/"bf16"/"pq", store format v2) — compressed
        spill shrinks every shard's bytes-read in the out-of-core
        serving path. ``keep_resident=False`` (requires ``spill_dir``)
        skips stacking the shards into HBM entirely: the engine holds
        only the spilled stores and every query runs the OOC path — on
        a MESH-FREE engine (``mesh=None`` + ``shards=N``) this is the
        only legal mode, and the shard count comes from ``self.shards``.
        ``replicas=R`` persists R on-disk copies of every shard store
        (the primary plus R-1 byte-identical replicas under
        spill_dir/replicas/rN/ — no re-encode, so pq codebooks and
        leaf payloads match bit for bit) with round-robin owner
        assignment; a failed or timed-out shard attempt fails over to
        the next copy before the query degrades (docs/FAULT.md). The
        delta/compaction fields govern :meth:`enable_writes`
        (docs/INGEST.md)."""
        ispec, sspec = coerce_build_args(self.method, index, store,
                                         legacy)
        spill_dir, codec = sspec.spill_dir, sspec.codec
        keep_resident, replicas = sspec.keep_resident, sspec.replicas
        params = ispec.build_params
        if self.mesh is None and keep_resident:
            raise ValueError(
                "mesh-free engine (mesh=None) cannot hold a resident "
                "index: build with StoreSpec(keep_resident=False, "
                "spill_dir=...)")
        key = key if key is not None else jax.random.PRNGKey(0)
        self._query_fns.clear()  # compiled against the previous index
        self.close()             # OOC state + compaction daemon from
        #                          the previous build
        self._delta = None       # writes belonged to the old rows
        self._seg_dir = None
        self._seg_seq = 0
        self._dead_cache.clear()
        self._dead_stacked = None
        self.method = ispec.method
        self.index_spec, self.store_spec = ispec, sspec
        n = data.shape[0]
        s = self.n_shards
        bounds = np.linspace(0, n, s + 1).astype(np.int64)
        sample = data[np.random.default_rng(0).choice(
            n, min(n, 100_000), replace=False)]
        hist = build_histogram(sample, key)  # GLOBAL histogram
        builder = _BUILDERS[ispec.method]

        shards = []
        spill_dirs = []
        for si in range(s):
            lo, hi = bounds[si], bounds[si + 1]
            idx = builder(data[lo:hi], hist=hist, key=key, **params)
            # re-map ids to global, keep global n_total for r_delta
            ids = np.asarray(idx.ids)
            ids = np.where(ids >= 0, ids + lo, -1)
            idx = dataclasses.replace(
                idx, ids=jnp.asarray(ids, jnp.int32), n_total=n)
            if spill_dir is not None:
                d = os.path.join(spill_dir, f"shard_{si:04d}")
                spill_dirs.append(idx.save(d, codec=codec))
                # replica copies are byte-identical file copies of the
                # saved store (same ids, histogram, pq codebook), laid
                # out under replicas/rN so open_spill's shard_*
                # discovery cannot mistake them for extra shards
                for rep in range(1, replicas):
                    rd = os.path.join(spill_dir, "replicas",
                                      f"r{rep}", f"shard_{si:04d}")
                    if os.path.isdir(rd):
                        shutil.rmtree(rd)
                    shutil.copytree(spill_dirs[-1], rd)
            if keep_resident:
                shards.append(idx)  # else: spilled, drop the HBM copy
        self.shard_dirs = tuple(spill_dirs) if spill_dirs else None
        self.shard_replica_dirs = _discover_replicas(
            spill_dir, self.shard_dirs) if spill_dirs else None
        if not keep_resident:
            self.stacked = None
            self._shard_ids_host = None
            return self

        # uniform static metadata + padded array shapes across shards
        max_leafL = max(sh.num_leaves for sh in shards)
        max_rows = max(sh.data.shape[0] for sh in shards)
        max_leaf = max(sh.max_leaf for sh in shards)
        arrs = {"box_lo": [], "box_hi": [], "offsets": [], "data": [],
                "ids": [], "row_norms": []}
        for sh in shards:
            L = sh.num_leaves
            off = np.asarray(sh.offsets)
            # pad leaves with empty extents pointing at the end
            offp = np.concatenate(
                [off, np.full(max_leafL - L, off[-1], off.dtype)])
            arrs["box_lo"].append(_pad_to(
                np.asarray(sh.box_lo), max_leafL, np.float32(1e30)))
            arrs["box_hi"].append(_pad_to(
                np.asarray(sh.box_hi), max_leafL, np.float32(1e30)))
            arrs["offsets"].append(offp)
            arrs["data"].append(_pad_to(
                np.asarray(sh.data), max_rows, np.float32(0)))
            arrs["ids"].append(_pad_to(
                np.asarray(sh.ids), max_rows, np.int64(-1)))
            # padding rows are all-zero, so norm 0 keeps the cache
            # consistent with the padded data
            arrs["row_norms"].append(_pad_to(
                np.asarray(sh.row_norms), max_rows, np.float32(0)))
        # host copies of the per-shard id arrays: the mutable tier
        # recomputes tombstone masks from these without device pulls
        self._shard_ids_host = [np.asarray(a) for a in arrs["ids"]]

        spec0 = P(self.axes if len(self.axes) > 1 else self.axes[0])

        def put(x):
            return jax.device_put(
                x, NamedSharding(self.mesh, spec0))

        base = shards[0]
        self.stacked = FrozenIndex(
            box_lo=put(jnp.asarray(np.stack(arrs["box_lo"]))),
            box_hi=put(jnp.asarray(np.stack(arrs["box_hi"]))),
            offsets=put(jnp.asarray(np.stack(arrs["offsets"]),
                                    jnp.int32)),
            data=put(jnp.asarray(np.stack(arrs["data"]))),
            ids=put(jnp.asarray(np.stack(arrs["ids"]), jnp.int32)),
            row_norms=put(jnp.asarray(np.stack(arrs["row_norms"]),
                                      jnp.float32)),
            weights=jax.device_put(
                base.weights, NamedSharding(self.mesh, P())),
            hist=DistanceHistogram(
                edges=jax.device_put(
                    hist.edges, NamedSharding(self.mesh, P())),
                cdf=jax.device_put(
                    hist.cdf, NamedSharding(self.mesh, P())),
            ),
            kind=base.kind, summary=base.summary,
            n_summary=base.n_summary, max_leaf=max_leaf,
            n_total=n, series_len=base.series_len,
        )
        return self

    # ------------- streaming writes (docs/INGEST.md) ------------------
    def _base_meta(self):
        """(n_total, series_len, hist) of the frozen base — from the
        stacked resident index when present, else from shard 0's
        spilled store (global metadata is replicated per shard)."""
        if self.stacked is not None:
            idx = self.stacked
            return int(idx.n_total), int(idx.series_len), idx.hist
        if not self.shard_dirs:
            raise ValueError("build() or open_spill() first")
        res = self._store(self.shard_dirs[0]).resident
        return int(res.n_total), int(res.series_len), res.hist

    def enable_writes(self) -> "DistributedEngine":
        """Arm the mutable tier (docs/INGEST.md): an in-memory
        :class:`repro.store.delta.DeltaTier` absorbing ``insert`` /
        ``delete`` at serving time — searched alongside the frozen
        store by every subsequent :meth:`query` — plus, when
        ``StoreSpec.auto_compact`` is set, the background daemon that
        re-freezes the delta into leaf-contiguous on-disk segments.
        Idempotent; ``insert``/``delete`` call it automatically."""
        from repro.store.delta import DeltaTier

        spec = self.store_spec or StoreSpec()
        if self._delta is None:
            # metadata reads (may open a store, takes _ooc_lock)
            # happen BEFORE _write_lock: _write_lock stays a leaf
            n_total, series_len, _ = self._base_meta()
            with self._write_lock:
                if self._delta is None:
                    if self._seg_dir is None:
                        if spec.spill_dir is not None:
                            self._seg_dir = os.path.join(
                                spec.spill_dir, "segments")
                            os.makedirs(self._seg_dir, exist_ok=True)
                        else:
                            self._seg_dir = tempfile.mkdtemp(
                                prefix="repro-segments-")
                    self._delta = DeltaTier(series_len,
                                            start_id=n_total)
        if spec.auto_compact:
            with self._write_lock:
                if self._compactor is None \
                        or not self._compactor.is_alive():
                    self._compactor_stop = threading.Event()
                    t = threading.Thread(
                        target=self._compact_loop,
                        name="delta-compactor", daemon=True)
                    self._compactor = t
                    t.start()
        return self

    def insert(self, rows, ids=None) -> np.ndarray:
        """Absorb rows into the delta tier at serving time; they are
        retrievable by the NEXT query() (bench_serve_load measures
        that freshness lag). Returns the assigned global ids
        (auto-allocated past the frozen id space when not supplied);
        inserting an existing id supersedes every older copy."""
        self.enable_writes()
        return self._delta.insert(rows, ids)

    def delete(self, ids) -> int:
        """Tombstone global ids everywhere — frozen base shards,
        compacted segments, and the delta memtable (kill-sequence
        rule, docs/INGEST.md)."""
        self.enable_writes()
        return self._delta.delete(ids)

    def compact(self) -> bool:
        """Re-freeze the live delta memtable into one leaf-contiguous
        on-disk segment (codec-aware via the ordinary save_index path)
        and publish it for serving. In-flight queries keep the
        snapshot they started with and never block; writes landing
        during the build go to the fresh active memtable. Returns True
        iff a segment was published. Runs on the background daemon
        when ``StoreSpec.auto_compact`` is set; safe to call manually
        either way (``begin_freeze`` serializes: a second concurrent
        compaction sees the freeze in flight and returns False)."""
        delta = self._delta
        if delta is None:
            return False
        batch = delta.begin_freeze()
        if batch is None:
            return False
        with obs.span("delta.compact", rows=int(batch.ids.shape[0])):
            try:
                seg = self._build_segment(batch)
            except BaseException:  # re-raised: the fold-back must run even for KeyboardInterrupt/SystemExit or the frozen batch's writes would be silently lost
                delta.abort_freeze()
                raise
            delta.publish_segment(seg)
        return True

    def _segment_codec(self) -> str:
        """The leaf codec segments are persisted with: the base
        shards' (so the rebuilt-from-scratch oracle store and the
        frozen+delta pair encode rows identically); falls back to the
        StoreSpec for resident-only engines."""
        if self.shard_dirs:
            return self._store(self.shard_dirs[0]).codec
        return (self.store_spec or StoreSpec()).codec

    def _build_segment(self, batch) -> EngineSegment:
        """Freeze one delta batch into an on-disk segment store: build
        a FrozenIndex over the batch rows with the SAME method/params
        as the base and the GLOBAL histogram (per-segment r_delta
        keeps single-node semantics, exactly like shards), re-map
        builder-local row ids to the batch's global ids, and save
        under segments/seg_NNNN with the base codec. Resident engines
        additionally keep the pre-encode f32 index for serving
        (EngineSegment docstring)."""
        n_base, _, hist = self._base_meta()
        ispec = self.index_spec or IndexSpec(method=self.method)
        builder = _BUILDERS[ispec.method]
        idx = builder(batch.rows, hist=hist,
                      key=jax.random.PRNGKey(0), **ispec.build_params)
        local_ids = np.asarray(idx.ids)
        gids = np.asarray(batch.ids, np.int64)
        ext = np.where(
            local_ids >= 0,
            gids[np.clip(local_ids, 0, gids.shape[0] - 1)], -1)
        idx = dataclasses.replace(
            idx, ids=jnp.asarray(ext, jnp.int32), n_total=n_base)
        with self._write_lock:  # leaf: segment numbering only
            seq = self._seg_seq
            self._seg_seq += 1
        d = os.path.join(self._seg_dir, f"seg_{seq:04d}")
        codec = self._segment_codec()
        if codec == "pq":
            from repro.store.layout import PQ_K
            if batch.rows.shape[0] < PQ_K:
                # pq codebooks train one centroid per code (PQ_K of
                # them) — a memtable smaller than that cannot train a
                # meaningful quantizer, and pq exists to shrink the
                # BIG frozen payload anyway: persist the small segment
                # lossless instead of crashing the compactor
                codec = "f32"
        idx.save(d, codec=codec)
        return EngineSegment(
            dir=d, born_seq=batch.born_seq,
            n_rows=int(batch.ids.shape[0]), ids_np=ext,
            index=idx if self.stacked is not None else None)

    def _compact_loop(self) -> None:
        """Body of the background compaction daemon
        (``StoreSpec.auto_compact``): poll the delta tier every
        ``compact_interval_s`` and compact once the live memtable
        crosses ``delta_max_rows``."""
        spec = self.store_spec or StoreSpec()
        stop = self._compactor_stop
        while not stop.wait(spec.compact_interval_s):
            delta = self._delta
            if delta is None or not delta.freeze_threshold_reached(
                    spec.delta_max_rows):
                continue
            try:
                self.compact()
            except Exception:  # noqa: BLE001 the daemon must outlive any one failed compaction (disk full, transient build error): the frozen batch already folded back into the memtable via abort_freeze, so count it and retry next tick
                obs.REGISTRY.counter("delta.compaction_errors").inc()

    def _stop_compactor(self) -> None:
        """Stop the compaction daemon if running (idempotent; close()
        and build() call it). The thread is joined OUTSIDE
        _write_lock — its body takes that lock for segment
        numbering."""
        with self._write_lock:
            t, self._compactor = self._compactor, None
            ev, self._compactor_stop = self._compactor_stop, None
        if ev is not None:
            ev.set()
        if t is not None and t.is_alive():
            t.join(timeout=10.0)

    def _mutable_view(self, snap) -> _MutView:
        """Precompute what serving one snapshot jointly needs: the
        joint r_delta N and every published segment's tombstone mask.
        ``base_dead`` counts kills landing in the frozen id range
        [0, n_base) — range-sharded build assigns exactly those ids —
        so deletes of never-inserted ids cost nothing."""
        n_base, _, _ = self._base_meta()
        base_dead = 0
        if snap.kills:
            kid = np.fromiter(snap.kills.keys(), np.int64,
                              count=len(snap.kills))
            base_dead = int(((kid >= 0) & (kid < n_base)).sum())
        seg_dead = []
        seg_live = 0
        for seg in snap.segments:
            m = self._unit_dead(("seg", seg.dir), seg.ids_np,
                                seg.born_seq, snap)
            seg_dead.append(m)
            seg_live += seg.n_rows - int(m.sum())
        joint_n = joint_n_total(n_base, base_dead,
                                seg_live + snap.live_rows)
        return _MutView(snap=snap, joint_n=joint_n,
                        seg_dead=tuple(seg_dead))

    def _unit_dead(self, unit, ids_np, born_seq: int, snap,
                   pad_to: Optional[int] = None) -> np.ndarray:
        """One frozen unit's tombstone mask under this snapshot,
        cached by kills_version (recomputing np.isin per query would
        dominate small-batch serving between writes). Lock-free like
        _query_fns: dict get/set are GIL-atomic, version equality
        keys the hit, and racing queries recompute interchangeable
        masks from their own consistent snapshots."""
        hit = self._dead_cache.get(unit)
        if hit is not None and hit[0] == snap.kills_version:
            mask = hit[1]
        else:
            mask = snap.dead_mask(ids_np, born_seq)
            self._dead_cache[unit] = (snap.kills_version, mask)
        if pad_to is not None and pad_to > mask.shape[0]:
            mask = np.pad(mask, (0, pad_to - mask.shape[0]))
        return mask

    # ------------------------------------------------------------------
    def query(
        self, queries, k: int, g: Guarantee = Guarantee(),
        visit_batch: int = 1, sync_bsf: bool = False,
        ooc: Optional[bool] = None, ooc_opts: Optional[dict] = None,
    ) -> QueryResult:
        """Batched distributed k-NN with the requested guarantee.

        Spill-built shards are first class: when the engine has no
        HBM-resident index (``build(keep_resident=False)`` or
        :meth:`open_spill`) the query runs the out-of-core path —
        detected automatically, or forced with ``ooc=True`` on an
        engine that holds both. ``ooc_opts`` forwards out-of-core
        knobs (share_gathers / cache_leaves / prefetch /
        prefetch_depth / rerank / frontier) to search_ooc, plus the
        fault-tolerance knobs the engine consumes itself
        (docs/FAULT.md): ``fault`` (a repro.fault.FaultInjector),
        ``retry`` (a serve.fault.RetryPolicy), ``workers`` (shard
        owner pool width; default min(n_shards, 8), 1 = the
        sequential fold). Per-shard caches stay warm across queries.

        Re-entrant: concurrent ``query()`` calls (the continuous-
        batching serving lanes each keep one in flight) return answers
        bit-exact to serial execution — per-query state travels on the
        returned :class:`QueryResult` (``.stats`` carries the
        aggregate per-shard OocStats, including the degradation block
        when a shard was lost past its replicas), and shared warm
        caches are serialized per shard copy so two queries never
        interleave on one slot pool."""
        # the mutable tier is snapshotted FIRST: everything below this
        # line — base shards, segments, memtable scan, tombstone
        # masks, joint N — serves one consistent point in time, however
        # many writes land while the query runs (docs/INGEST.md)
        mut = None
        if self._delta is not None:
            snap = self._delta.snapshot()
            if snap.live_rows or snap.kills or snap.segments:
                mut = self._mutable_view(snap)
        if ooc is None:
            ooc = self.stacked is None and self.shard_dirs is not None
        if ooc:
            if sync_bsf:
                # the sequential per-shard host loops do not exchange
                # a running best-so-far yet (each shard prunes against
                # its own) — seeding shard i+1's pool from the fold of
                # shards 0..i is the ROADMAP follow-up; until then the
                # flag must not be silently swallowed
                warnings.warn(
                    "sync_bsf is not supported on the out-of-core "
                    "path: shards are searched without cross-shard "
                    "best-so-far exchange (results are identical, "
                    "bytes-read/leaves-visited are not tightened).",
                    UserWarning, stacklevel=2)
            return self._query_ooc(queries, k, g, visit_batch,
                                   dict(ooc_opts or {}), mut=mut)
        assert self.stacked is not None, "build() first"
        idx = self.stacked
        b = queries.shape[0]
        if mut is not None:
            return self._query_resident_mut(idx, queries, k, g,
                                            visit_batch, sync_bsf, mut)
        cache_key = (k, g.delta, g.epsilon, g.nprobe, visit_batch,
                     sync_bsf, b, queries.shape[-1])
        cached = self._query_fns.get(cache_key)
        if cached is not None:
            return self._run_resident(cached, idx, queries, k, b)
        axes = self.axes
        spec_shard = P(axes if len(axes) > 1 else axes[0])
        in_specs = (
            FrozenIndex(
                box_lo=spec_shard, box_hi=spec_shard, offsets=spec_shard,
                data=spec_shard, ids=spec_shard, weights=P(),
                hist=DistanceHistogram(edges=P(), cdf=P()),
                kind=idx.kind, summary=idx.summary,
                n_summary=idx.n_summary, max_leaf=idx.max_leaf,
                n_total=idx.n_total, series_len=idx.series_len,
                row_norms=spec_shard,
            ),
            P(),  # queries replicated
        )

        delta, epsilon, nprobe = g.delta, g.epsilon, g.nprobe

        def local(idx_local: FrozenIndex, q) -> SearchResult:
            # strip the leading shard axis (size 1 per shard)
            sq = jax.tree_util.tree_map(
                lambda a: a[0], (idx_local.box_lo, idx_local.box_hi,
                                 idx_local.offsets, idx_local.data,
                                 idx_local.ids, idx_local.row_norms))
            lidx = dataclasses.replace(
                idx_local, box_lo=sq[0], box_hi=sq[1], offsets=sq[2],
                data=sq[3], ids=sq[4], row_norms=sq[5])
            # search_impl, not search: an inner jit under shard_map
            # miscompiles the refinement loop on jax 0.4.x.
            # repro: allow[jax-while-shard-map] deliberate: this closure is dispatched ONLY through the eager compat.shard_map below (never under jit) precisely because of the 0.4.37 miscompile — ROADMAP pin notes
            res = search_impl(
                lidx, q, k, delta=delta, epsilon=epsilon,
                nprobe=nprobe, visit_batch=visit_batch,
                sync_axes=tuple(axes) if sync_bsf else ())
            # gather per-shard top-k along a new leading axis and merge
            all_d = jax.lax.all_gather(res.dists, axes[-1], tiled=False)
            all_i = jax.lax.all_gather(res.ids, axes[-1], tiled=False)
            if len(axes) > 1:
                for ax in axes[:-1]:
                    all_d = jax.lax.all_gather(all_d, ax, tiled=False)
                    all_i = jax.lax.all_gather(all_i, ax, tiled=False)
                all_d = all_d.reshape(-1, b, k)
                all_i = all_i.reshape(-1, b, k)
            md = all_d.transpose(1, 0, 2).reshape(b, -1)
            mi = all_i.transpose(1, 0, 2).reshape(b, -1)
            sd, si = jax.lax.sort((md, mi), num_keys=1)
            leaves = jax.lax.psum(res.leaves_visited, axes)
            rows = jax.lax.psum(res.rows_scanned, axes)
            lbs = jax.lax.psum(res.lb_computed, axes)
            return SearchResult(sd[:, :k], si[:, :k], leaves, rows, lbs)

        out_specs = SearchResult(P(), P(), P(), P(), P())
        # The shard_map'ed fn is called EAGERLY on purpose: on jax
        # 0.4.x, putting this under jax.jit (inner OR outer) miscompiles
        # the refinement while_loop — verified wrong neighbors on
        # 0.4.37; eager execution is correct. Reusing the same wrapped
        # callable via _query_fns still avoids per-call closure
        # rebuilding and retracing.
        fn = compat.shard_map(
            local, mesh=self.mesh, in_specs=in_specs,
            out_specs=out_specs, check=False,
        )
        self._query_fns[cache_key] = fn
        return self._run_resident(fn, idx, queries, k, b)

    def _run_resident(self, fn, idx, queries, k: int, b: int
                      ) -> QueryResult:
        """Dispatch the (cached) shard_map'ed resident query, wrapped
        in a span when tracing is enabled. The block_until_ready is
        span-only: the untraced path keeps its async dispatch. The
        resident path has no I/O to account, so ``stats`` is None —
        thread-safe by construction (eager shard_map dispatch touches
        no per-query engine state)."""
        if not obs.enabled():
            return QueryResult(*fn(idx, queries))
        with obs.span("engine.query", path="resident", lanes=b, k=k,
                      shards=self.n_shards) as sp:
            res = fn(idx, queries)
            jax.block_until_ready(res.dists)
            sp.set(leaves_visited=int(np.asarray(
                       res.leaves_visited).sum()),
                   rows_scanned=int(np.asarray(res.rows_scanned).sum()))
        return QueryResult(*res)

    def _dead_stacked_dev(self, mut: _MutView):
        """The [S, max_rows] stacked tombstone operand for the
        resident shard_map (device-put with the shard axis on the
        mesh), rebuilt only when the kill set advances — the
        steady-state query between writes reuses the cached device
        array. Same lock-free versioned-cache discipline as
        _dead_cache."""
        snap = mut.snap
        hit = self._dead_stacked
        if hit is not None and hit[0] == snap.kills_version:
            return hit[1]
        ids_host = self._shard_ids_host
        if ids_host is None:  # e.g. checkpoint-restored stacked index
            ids_host = [np.asarray(a)
                        for a in np.asarray(self.stacked.ids)]
            self._shard_ids_host = ids_host
        masks = np.stack([
            self._unit_dead(("rshard", si), ids, 0, snap)
            for si, ids in enumerate(ids_host)])
        spec0 = P(self.axes if len(self.axes) > 1 else self.axes[0])
        dev = jax.device_put(jnp.asarray(masks),
                             NamedSharding(self.mesh, spec0))
        self._dead_stacked = (snap.kills_version, dev)
        return dev

    def _query_resident_mut(self, idx, queries, k: int, g: Guarantee,
                            visit_batch: int, sync_bsf: bool,
                            mut: _MutView) -> QueryResult:
        """The resident path with the mutable tier armed: the same
        eager shard_map search as :meth:`query`, plus (a) the
        per-shard tombstone mask as a third operand and (b) the joint
        live-N for r_delta — then the segment + memtable fold
        (:meth:`_fold_mutable`). The closure is rebuilt per call: it
        closes over joint_n, which moves with every insert, and
        dispatch is eager anyway (no compile cache to protect —
        _query_fns exists to avoid RETRACING, which eager closures
        never do)."""
        g.validate()
        b = queries.shape[0]
        axes = self.axes
        spec_shard = P(axes if len(axes) > 1 else axes[0])
        in_specs = (
            FrozenIndex(
                box_lo=spec_shard, box_hi=spec_shard, offsets=spec_shard,
                data=spec_shard, ids=spec_shard, weights=P(),
                hist=DistanceHistogram(edges=P(), cdf=P()),
                kind=idx.kind, summary=idx.summary,
                n_summary=idx.n_summary, max_leaf=idx.max_leaf,
                n_total=idx.n_total, series_len=idx.series_len,
                row_norms=spec_shard,
            ),
            spec_shard,  # [S, max_rows] tombstones, one row per shard
            P(),         # queries replicated
        )
        delta, epsilon, nprobe = g.delta, g.epsilon, g.nprobe
        joint_n = mut.joint_n

        def local_mut(idx_local: FrozenIndex, dead_l, q) -> SearchResult:
            sq = jax.tree_util.tree_map(
                lambda a: a[0], (idx_local.box_lo, idx_local.box_hi,
                                 idx_local.offsets, idx_local.data,
                                 idx_local.ids, idx_local.row_norms))
            lidx = dataclasses.replace(
                idx_local, box_lo=sq[0], box_hi=sq[1], offsets=sq[2],
                data=sq[3], ids=sq[4], row_norms=sq[5])
            # search_impl, not search: an inner jit under shard_map
            # miscompiles the refinement loop on jax 0.4.x.
            # repro: allow[jax-while-shard-map] deliberate: dispatched ONLY through the eager compat.shard_map below (never under jit), same 0.4.37 miscompile rationale as the immutable closure above
            res = search_impl(
                lidx, q, k, delta=delta, epsilon=epsilon,
                nprobe=nprobe, visit_batch=visit_batch,
                dead=dead_l[0], n_override=joint_n,
                sync_axes=tuple(axes) if sync_bsf else ())
            all_d = jax.lax.all_gather(res.dists, axes[-1], tiled=False)
            all_i = jax.lax.all_gather(res.ids, axes[-1], tiled=False)
            if len(axes) > 1:
                for ax in axes[:-1]:
                    all_d = jax.lax.all_gather(all_d, ax, tiled=False)
                    all_i = jax.lax.all_gather(all_i, ax, tiled=False)
                all_d = all_d.reshape(-1, b, k)
                all_i = all_i.reshape(-1, b, k)
            md = all_d.transpose(1, 0, 2).reshape(b, -1)
            mi = all_i.transpose(1, 0, 2).reshape(b, -1)
            sd, si = jax.lax.sort((md, mi), num_keys=1)
            leaves = jax.lax.psum(res.leaves_visited, axes)
            rows = jax.lax.psum(res.rows_scanned, axes)
            lbs = jax.lax.psum(res.lb_computed, axes)
            return SearchResult(sd[:, :k], si[:, :k], leaves, rows, lbs)

        out_specs = SearchResult(P(), P(), P(), P(), P())
        fn = compat.shard_map(
            local_mut, mesh=self.mesh, in_specs=in_specs,
            out_specs=out_specs, check=False,
        )
        dead_dev = self._dead_stacked_dev(mut)
        qj = jnp.asarray(queries)
        if not obs.enabled():
            base = QueryResult(*fn(idx, dead_dev, qj))
            return self._fold_mutable(base, mut, qj, k, g,
                                      visit_batch, resident=True)
        with obs.span("engine.query", path="resident+delta", lanes=b,
                      k=k, shards=self.n_shards,
                      delta_rows=mut.snap.live_rows,
                      segments=len(mut.snap.segments)) as sp:
            res = fn(idx, dead_dev, qj)
            jax.block_until_ready(res.dists)
            out = self._fold_mutable(QueryResult(*res), mut, qj, k, g,
                                     visit_batch, resident=True)
            sp.set(leaves_visited=int(np.asarray(
                       out.leaves_visited).sum()),
                   rows_scanned=int(np.asarray(out.rows_scanned).sum()))
        return out

    def _fold_mutable(self, base: QueryResult, mut: _MutView, qj,
                      k: int, g: Guarantee, visit_batch: int, *,
                      resident: bool) -> QueryResult:
        """Fold the mutable tier into the frozen-base answer: every
        published segment is served as one more shard — resident
        engines score the kept pre-encode index with the shared eager
        search_impl (same arithmetic as the resident base), OOC
        engines serve the segment's on-disk store through search_ooc
        (codec-faithful) — and the memtable snapshot is brute-scored
        last (store.delta.search_snapshot), all through
        ``ops.topk_merge_unique``. The kill rule guarantees at most
        one live copy of any id across the operands, the merge's
        distinct-id precondition; the merge is a commutative
        (d, id)-lex selection, so this staged fold equals the
        from-scratch rebuild's single sort bit for bit."""
        from repro.store.delta import search_snapshot
        from repro.store.ooc import search_ooc

        snap = mut.snap
        top_d, top_i = base.dists, base.ids
        leaves = np.asarray(base.leaves_visited, np.int64).copy()
        rows = np.asarray(base.rows_scanned, np.int64).copy()
        lbs = int(base.lb_computed)
        b = qj.shape[0]
        for seg, dead in zip(snap.segments, mut.seg_dead):
            dead_arg = jnp.asarray(dead) if dead.any() else None
            if resident and seg.index is not None:
                res = search_impl(
                    seg.index, qj, k, delta=g.delta,
                    epsilon=g.epsilon, nprobe=g.nprobe,
                    visit_batch=visit_batch, dead=dead_arg,
                    n_override=mut.joint_n)
                sd, si = res.dists, res.ids
                leaves += np.asarray(res.leaves_visited, np.int64)
                rows += np.asarray(res.rows_scanned, np.int64)
                lbs += int(res.lb_computed)
            else:
                with self._copy_lock(seg.dir):
                    store = self._store(seg.dir)
                    cache = self._shard_cache(
                        seg.dir, store, b * visit_batch, None,
                        prefetch_depth=1, prefetch=True)
                    out = search_ooc(
                        store, qj, k, g, visit_batch=visit_batch,
                        cache=cache, dead=dead_arg,
                        n_override=mut.joint_n)
                r = out.result
                sd, si = r.dists, r.ids
                leaves += np.asarray(r.leaves_visited, np.int64)
                rows += np.asarray(r.rows_scanned, np.int64)
                lbs += int(r.lb_computed)
            top_d, top_i = ops.topk_merge_unique(sd, si, top_d, top_i)
        sd, si = search_snapshot(
            snap, qj, k,
            codec="f32" if resident else self._segment_codec())
        top_d, top_i = ops.topk_merge_unique(sd, si, top_d, top_i)
        rows += snap.live_rows  # the memtable scan touches every row
        return QueryResult(
            dists=top_d, ids=top_i,
            leaves_visited=jnp.asarray(leaves, jnp.int32),
            rows_scanned=jnp.asarray(rows, jnp.int32),
            lb_computed=jnp.int32(lbs),
            stats=base.stats,
        )

    # ------------------------------------------------------------------
    def _copy_lock(self, d: str) -> threading.RLock:
        """The serving lock for one shard store copy (lazily created
        under ``_ooc_lock``, held for a whole per-shard search):
        concurrent queries — serving lanes each keep one in flight —
        serialize per copy because the warm DeviceLeafCache slot pool
        is single-query state (another query's get_slots may evict a
        slot this one is about to gather from, which would break the
        bit-exact-vs-serial contract). Within one query the shard
        owners touch DISTINCT copies, so PR 8's concurrent fold is
        unaffected."""
        with self._ooc_lock:
            lk = self._copy_locks.get(d)
            if lk is None:
                lk = self._copy_locks[d] = threading.RLock()
            return lk

    def _store(self, d: str):
        """The (lazily opened, cached) store for one shard copy —
        lock-guarded: concurrent shard owners open their stores in
        parallel on the first query."""
        with self._ooc_lock:
            store = self._stores.get(d)
        if store is not None:
            return store
        from repro.store import load_index
        store = load_index(d, resident="summaries")
        with self._ooc_lock:
            # a concurrent open of the same dir (close() racing a
            # query) keeps the first registered handle
            return self._stores.setdefault(d, store)

    def _shard_cache(self, d: str, store, need_leaves: int,
                     cache_leaves: Optional[int], *,
                     prefetch_depth: int, prefetch: bool):
        """The shard copy's persistent warm cache + prefetcher,
        re-validated per query: a cache whose capacity cannot pin this
        query's per-iteration working set (b * visit_batch leaves —
        batch sizes vary per guarantee group in the serving front) is
        retired and rebuilt larger, and the prefetcher thread persists
        with the cache instead of being spawned and joined per query
        (its staging depth grows with the requested lookahead).

        Runs under ``_ooc_lock`` end to end: owners touch DISTINCT
        dirs so the serialization costs nothing on the steady path,
        and it makes the dict re-validation atomic against a
        concurrent ``close()`` (mid-query close retires the cache;
        this query keeps its own reference and finishes on it)."""
        from repro.store import DeviceLeafCache, LeafPrefetcher

        need = max(int(need_leaves), 1)
        with self._ooc_lock:
            cache = self._shard_caches.get(d)
            if cache is not None \
                    and cache.capacity < min(need,
                                             max(store.num_leaves, 1)):
                if cache.prefetcher is not None:
                    cache.prefetcher.close()
                    cache.prefetcher = None
                cache = None
            if cache is None:
                cap = cache_leaves if cache_leaves is not None \
                    else max(store.num_leaves // 8, 1)
                cap = min(max(cap, need), max(store.num_leaves, 1))
                cache = DeviceLeafCache(store, cap)
                self._shard_caches[d] = cache
            else:
                # warm CONTENTS persist across queries (the serving
                # regime); counters reset so QueryResult.stats reports
                # this query's bytes, not the cache's lifetime
                cache.reset_counters()
            if prefetch:
                depth = max(2, prefetch_depth + 1)
                if cache.prefetcher is not None \
                        and cache.prefetcher.depth < depth:
                    cache.prefetcher.close()
                    cache.prefetcher = None
                if cache.prefetcher is None:
                    cache.prefetcher = LeafPrefetcher(store,
                                                      depth=depth)
        return cache

    def close(self) -> None:
        """Release out-of-core serving state: stop every per-shard
        prefetcher thread and drop the warm caches/stores. build()
        calls this before rebuilding; harmless on a resident-only
        engine. Idempotent and thread-safe: state is snapshotted and
        detached under the lock, prefetcher threads are joined outside
        it (a query in flight keeps its own cache reference and falls
        back to demand reads once its prefetcher stops). The delta
        tier's DATA survives a close — only the compaction daemon
        stops (a later insert()/enable_writes() restarts it);
        build() additionally resets the tier for the new rows."""
        self._stop_compactor()
        with self._ooc_lock:
            caches = list(self._shard_caches.values())
            self._shard_caches.clear()
            self._stores.clear()
        for cache in caches:
            if cache.prefetcher is not None:
                cache.prefetcher.close()
                cache.prefetcher = None

    def _query_ooc(self, queries, k: int, g: Guarantee,
                   visit_batch: int, opts: dict,
                   mut: Optional[_MutView] = None) -> QueryResult:
        """Serve the query batch from the spilled shard stores:
        CONCURRENT shard owners (one worker per shard, pool width
        ``workers``) each drive the host refinement loop over their
        store — the SAME shared core search_impl traces
        (core/refine.py) — and stream their answers into a cross-shard
        ``ops.topk_merge_unique`` fold on this thread as they land.
        Completion order cannot change the answer: the merge is a
        commutative, associative (d, id)-lex selection over globally
        disjoint ids, so the fold equals the sequential fold bit for
        bit. Parity with the resident shard_map path: per-shard
        results are bit-exact to the resident per-shard search for
        lossless codecs (tests/test_store.py) and both merges select
        the k smallest distances — so ids AND dists match the resident
        engine answer bit-for-bit (modulo cross-shard ties, which
        (d, id)-lex ordering resolves deterministically). Guarantee
        preservation is the same argument as the shard_map path
        (module docstring): every shard's answer satisfies the local
        guarantee against the GLOBAL histogram/n_total persisted in
        its store, and the merge only improves each rank.

        Fault tolerance (docs/FAULT.md): each shard serve runs under
        serve/fault.serve_shard_with_failover — retries with capped
        backoff across the shard's store copies (round-robin owner
        first), per-attempt deadlines checked cooperatively inside the
        host loop, a persistent circuit breaker skipping copies that
        keep failing. A shard lost past every copy degrades the
        answer instead of failing the query: the fold completes over
        the survivors and the returned ``QueryResult.stats`` carries
        ``degraded`` / ``shards_lost`` / ``effective_delta`` with delta recomputed
        from the global histogram mass the missing rows own
        (core.guarantees.effective_delta_after_loss)."""
        from repro.serve import fault as sfault
        from repro.store.ooc import search_ooc

        from .guarantees import effective_delta_after_loss

        if not self.shard_dirs:
            raise ValueError(
                "no spilled shards: build(spill_dir=...) or "
                "open_spill() first")
        g.validate()
        qj = jnp.asarray(queries)
        b = qj.shape[0]
        cache_leaves = opts.pop("cache_leaves", None)
        injector = opts.pop("fault", None)
        policy = opts.pop("retry", None) or sfault.RetryPolicy()
        n_sh = len(self.shard_dirs)
        workers = int(opts.pop("workers", 0) or min(n_sh, 8))
        prefetch_depth = int(opts.get("prefetch_depth", 1))
        prefetch = bool(opts.get("prefetch", True))
        replica_dirs = self.shard_replica_dirs \
            or tuple((d,) for d in self.shard_dirs)
        with self._ooc_lock:
            if self._breaker is None:
                self._breaker = sfault.CircuitBreaker()
            breaker = self._breaker

        def attempt_for(si):
            def attempt(d, fctx):
                # one query's use of one copy is one critical section
                # (_copy_lock): cache revalidation, counter window and
                # slot-pool occupancy stay single-query even when
                # serving lanes race on the same shard. An attempt
                # that waits out its deadline here fails on its first
                # in-loop check and falls over to another copy — a
                # DIFFERENT lock — instead of queueing forever.
                with self._copy_lock(d):
                    store = self._store(d)
                    cache = self._shard_cache(
                        d, store, b * visit_batch, cache_leaves,
                        prefetch_depth=prefetch_depth,
                        prefetch=prefetch)
                    dead = None
                    n_over = None
                    if mut is not None:
                        # replica copies are byte-identical to the
                        # primary (same ids array), so the mask is
                        # keyed by SHARD, shared across copies
                        m = self._unit_dead(
                            ("sshard", si),
                            np.asarray(store.resident.ids), 0,
                            mut.snap, pad_to=store.mmap.shape[0])
                        dead = m if m.any() else None
                        n_over = mut.joint_n
                    # the child ooc.query span carries the shard's
                    # bytes_read attr — one subtree level owns each
                    # numeric attr, so QueryProfile.total() never
                    # double-counts. Worker-thread spans root their
                    # own per-thread subtree (obs/trace.py).
                    with obs.span("engine.shard", shard=si,
                                  copy=fctx.replica):
                        return search_ooc(
                            store, qj, k, g,
                            visit_batch=visit_batch, cache=cache,
                            fault=fctx, dead=dead,
                            n_override=n_over, **opts)
            return attempt

        def serve_one(si):
            copies = replica_dirs[si]
            # round-robin ownership: shard si's owner is copy
            # (si % R); failover walks the remaining copies in order
            order = tuple(copies[(si + j) % len(copies)]
                          for j in range(len(copies)))
            return sfault.serve_shard_with_failover(
                attempt_for(si), shard=si, replica_dirs=order,
                policy=policy, breaker=breaker, injector=injector)

        top_d = jnp.full((b, k), jnp.inf, jnp.float32)
        top_i = jnp.full((b, k), -1, jnp.int32)
        leaves = np.zeros(b, np.int64)
        rows = np.zeros(b, np.int64)
        lbs = 0
        per_shard = []
        infos = []
        lost = []
        with obs.span("engine.query", path="ooc", lanes=b, k=k,
                      shards=n_sh, workers=workers) as root:

            def fold(si, served):
                out, info = served
                out.stats.retries = info.retries
                out.stats.failovers = info.failovers
                obs.REGISTRY.counter(
                    "engine.shard.bytes_read", shard=str(si)).inc(
                        out.stats.bytes_read)
                r = out.result
                # shard dists are already sqrt'd like the resident
                # merge operands; ids are globally disjoint across
                # shards, so the unique-merge's dedup is a no-op — it
                # is used for its (d, id)-lex selection and its
                # explicit precondition
                nonlocal top_d, top_i, lbs, leaves, rows
                top_d, top_i = ops.topk_merge_unique(
                    r.dists, r.ids, top_d, top_i)
                leaves += np.asarray(r.leaves_visited, np.int64)
                rows += np.asarray(r.rows_scanned, np.int64)
                lbs += int(r.lb_computed)
                per_shard.append(out.stats)
                infos.append(info)

            if workers <= 1 or n_sh == 1:
                # sequential fold: no worker threads, spans nest
                # under this root exactly as before PR 8
                for si in range(n_sh):
                    try:
                        served = serve_one(si)
                    except sfault.ShardLost:
                        lost.append(si)
                        continue
                    fold(si, served)
            else:
                with ThreadPoolExecutor(
                        max_workers=min(workers, n_sh),
                        thread_name_prefix="shard-owner") as ex:
                    futs = {ex.submit(serve_one, si): si
                            for si in range(n_sh)}
                    for fut in as_completed(futs):
                        si = futs[fut]
                        try:
                            served = fut.result()
                        except sfault.ShardLost:
                            lost.append(si)
                            continue
                        fold(si, served)
            if len(lost) == n_sh:
                raise sfault.ShardLost(
                    -1, RuntimeError(
                        f"every shard lost ({sorted(lost)}): no "
                        "surviving answer to degrade to"))
            stats = OocStats.aggregate(per_shard)
            stats.effective_delta = float(g.delta)
            if lost:
                self._degrade(stats, sorted(lost), infos, top_d, k, g,
                              effective_delta_after_loss)
                root.set(degraded=True, shards_lost=stats.shards_lost,
                         effective_delta=stats.effective_delta)
            root.set(bytes_read_total=stats.bytes_read,
                     iterations=stats.iterations)
        out = QueryResult(
            dists=top_d, ids=top_i,
            leaves_visited=jnp.asarray(leaves, jnp.int32),
            rows_scanned=jnp.asarray(rows, jnp.int32),
            lb_computed=jnp.int32(lbs),
            stats=stats,
        )
        if mut is not None:
            out = self._fold_mutable(out, mut, qj, k, g, visit_batch,
                                     resident=False)
        return out

    def _degrade(self, stats: OocStats, lost, infos, top_d, k: int,
                 g: Guarantee, effective_delta_after_loss) -> None:
        """Downgrade the answer's guarantee honestly after shard loss:
        count the rows the fold never saw (global n_total minus the
        survivors' real rows — robust to uneven range-sharding) and
        recompute delta from the global histogram mass those rows own
        at each lane's surviving kth distance. The result is a
        delta-epsilon guarantee whatever the request was — exact and
        epsilon claims cannot survive unseen rows."""
        surv = [self._store(i.served_dir) for i in infos]
        n_total = int(surv[0].resident.n_total)
        n_seen = sum(
            int((np.asarray(s.resident.ids) >= 0).sum()) for s in surv)
        n_lost = max(n_total - n_seen, 0)
        stats.degraded = True
        stats.shards_lost = len(lost)
        stats.effective_delta = effective_delta_after_loss(
            surv[0].resident.hist, np.asarray(top_d[:, k - 1]),
            n_lost, delta=g.delta, epsilon=g.epsilon)
        obs.REGISTRY.counter("engine.degraded_queries").inc()
        obs.REGISTRY.counter("engine.shards_lost").inc(len(lost))
        warnings.warn(
            f"shards {lost} lost past retries and replicas: answer "
            f"degraded to delta-epsilon with effective_delta="
            f"{stats.effective_delta:.3g} over {n_lost} unseen rows "
            "(docs/FAULT.md)", UserWarning, stacklevel=4)
