"""DistributedSearchEngine — the paper's methods at pod scale.

The collection is range-sharded over the mesh's data-parallel axes; each
shard owns a FrozenIndex over its rows (ids stay global) plus the GLOBAL
distance histogram and global N, so per-shard r_delta matches the
single-node semantics. A query batch is replicated to all shards, each
runs the batched Algorithm 2 locally (shard_map), and per-shard top-k
rows are merged with an all-gather + static sort.

Guarantee preservation under sharding (docs/PERF.md §6): every global true
r-th NN lives in some shard where it ranks <= r locally; the local
guarantee bounds that shard's reported r-th by (1+eps) x local true r-th
<= (1+eps) x global true r-th, and the merged r-th best across shards
only improves — so exact/epsilon/delta-epsilon transfer. For delta<1 the
per-shard stopping radius uses the global N, making each shard's early
stop conservative w.r.t. the global distribution.

Fault tolerance: the frozen artifact checkpoints via train/checkpoint.py
like any pytree; straggler mitigation degrades the guarantee to
ng(nprobe) under a deadline — the taxonomy is the mitigation (paper
Fig. 8 shows the first bsf is already near-exact).
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat

from .guarantees import Guarantee
from .histogram import DistanceHistogram, build_histogram
from .index import FrozenIndex
from .indexes import dstree, isax, vafile
from .search import SearchResult, search_impl

_BUILDERS = {
    "isax2+": isax.build,
    "dstree": dstree.build,
    "va+file": vafile.build,
}


def _pad_to(arr: np.ndarray, target: int, fill) -> np.ndarray:
    if arr.shape[0] == target:
        return arr
    pad = np.full((target - arr.shape[0],) + arr.shape[1:], fill,
                  arr.dtype)
    return np.concatenate([arr, pad], axis=0)


@dataclasses.dataclass
class DistributedEngine:
    mesh: Mesh
    axes: Tuple[str, ...] = ("data",)
    method: str = "dstree"
    stacked: Optional[FrozenIndex] = None  # leading shard axis on arrays
    shard_dirs: Optional[Tuple[str, ...]] = None  # spilled store dirs
    # jitted query fns keyed by (k, guarantee, batch shape, ...): the
    # shard_map body closes over those values, so a fresh closure per
    # call would defeat jit's compile cache
    _query_fns: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    @property
    def n_shards(self) -> int:
        shape = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        out = 1
        for a in self.axes:
            out *= shape[a]
        return out

    # ------------------------------------------------------------------
    def build(self, data: np.ndarray, key=None,
              spill_dir: Optional[str] = None, codec: str = "f32",
              **params):
        """Shard rows, build per-shard indexes (embarrassingly parallel
        on hosts), stack and device_put with the shard axis mapped onto
        the mesh axes.

        ``spill_dir`` additionally persists every shard as an on-disk
        store artifact (spill_dir/shard_NNNN, global ids and global
        n_total preserved) so shards can later be served out-of-core
        via FrozenIndex.load(..., resident="summaries") + search_ooc —
        the path toward collections larger than pod HBM. ``codec``
        selects each shard's leaf payload encoding ("f32"/"bf16"/"pq",
        store format v2) — compressed spill shrinks every shard's
        bytes-read in the out-of-core serving path."""
        key = key if key is not None else jax.random.PRNGKey(0)
        self._query_fns.clear()  # compiled against the previous index
        n = data.shape[0]
        s = self.n_shards
        bounds = np.linspace(0, n, s + 1).astype(np.int64)
        sample = data[np.random.default_rng(0).choice(
            n, min(n, 100_000), replace=False)]
        hist = build_histogram(sample, key)  # GLOBAL histogram
        builder = _BUILDERS[self.method]

        shards = []
        spill_dirs = []
        for si in range(s):
            lo, hi = bounds[si], bounds[si + 1]
            idx = builder(data[lo:hi], hist=hist, key=key, **params)
            # re-map ids to global, keep global n_total for r_delta
            ids = np.asarray(idx.ids)
            ids = np.where(ids >= 0, ids + lo, -1)
            idx = dataclasses.replace(
                idx, ids=jnp.asarray(ids, jnp.int32), n_total=n)
            if spill_dir is not None:
                d = os.path.join(spill_dir, f"shard_{si:04d}")
                spill_dirs.append(idx.save(d, codec=codec))
            shards.append(idx)
        self.shard_dirs = tuple(spill_dirs) if spill_dirs else None

        # uniform static metadata + padded array shapes across shards
        max_leafL = max(sh.num_leaves for sh in shards)
        max_rows = max(sh.data.shape[0] for sh in shards)
        max_leaf = max(sh.max_leaf for sh in shards)
        arrs = {"box_lo": [], "box_hi": [], "offsets": [], "data": [],
                "ids": [], "row_norms": []}
        for sh in shards:
            L = sh.num_leaves
            off = np.asarray(sh.offsets)
            # pad leaves with empty extents pointing at the end
            offp = np.concatenate(
                [off, np.full(max_leafL - L, off[-1], off.dtype)])
            arrs["box_lo"].append(_pad_to(
                np.asarray(sh.box_lo), max_leafL, np.float32(1e30)))
            arrs["box_hi"].append(_pad_to(
                np.asarray(sh.box_hi), max_leafL, np.float32(1e30)))
            arrs["offsets"].append(offp)
            arrs["data"].append(_pad_to(
                np.asarray(sh.data), max_rows, np.float32(0)))
            arrs["ids"].append(_pad_to(
                np.asarray(sh.ids), max_rows, np.int64(-1)))
            # padding rows are all-zero, so norm 0 keeps the cache
            # consistent with the padded data
            arrs["row_norms"].append(_pad_to(
                np.asarray(sh.row_norms), max_rows, np.float32(0)))

        spec0 = P(self.axes if len(self.axes) > 1 else self.axes[0])

        def put(x):
            return jax.device_put(
                x, NamedSharding(self.mesh, spec0))

        base = shards[0]
        self.stacked = FrozenIndex(
            box_lo=put(jnp.asarray(np.stack(arrs["box_lo"]))),
            box_hi=put(jnp.asarray(np.stack(arrs["box_hi"]))),
            offsets=put(jnp.asarray(np.stack(arrs["offsets"]),
                                    jnp.int32)),
            data=put(jnp.asarray(np.stack(arrs["data"]))),
            ids=put(jnp.asarray(np.stack(arrs["ids"]), jnp.int32)),
            row_norms=put(jnp.asarray(np.stack(arrs["row_norms"]),
                                      jnp.float32)),
            weights=jax.device_put(
                base.weights, NamedSharding(self.mesh, P())),
            hist=DistanceHistogram(
                edges=jax.device_put(
                    hist.edges, NamedSharding(self.mesh, P())),
                cdf=jax.device_put(
                    hist.cdf, NamedSharding(self.mesh, P())),
            ),
            kind=base.kind, summary=base.summary,
            n_summary=base.n_summary, max_leaf=max_leaf,
            n_total=n, series_len=base.series_len,
        )
        return self

    # ------------------------------------------------------------------
    def query(
        self, queries, k: int, g: Guarantee = Guarantee(),
        visit_batch: int = 1, sync_bsf: bool = False,
    ) -> SearchResult:
        """Batched distributed k-NN with the requested guarantee."""
        assert self.stacked is not None, "build() first"
        idx = self.stacked
        b = queries.shape[0]
        cache_key = (k, g.delta, g.epsilon, g.nprobe, visit_batch,
                     sync_bsf, b, queries.shape[-1])
        cached = self._query_fns.get(cache_key)
        if cached is not None:
            return cached(idx, queries)
        axes = self.axes
        spec_shard = P(axes if len(axes) > 1 else axes[0])
        in_specs = (
            FrozenIndex(
                box_lo=spec_shard, box_hi=spec_shard, offsets=spec_shard,
                data=spec_shard, ids=spec_shard, weights=P(),
                hist=DistanceHistogram(edges=P(), cdf=P()),
                kind=idx.kind, summary=idx.summary,
                n_summary=idx.n_summary, max_leaf=idx.max_leaf,
                n_total=idx.n_total, series_len=idx.series_len,
                row_norms=spec_shard,
            ),
            P(),  # queries replicated
        )

        delta, epsilon, nprobe = g.delta, g.epsilon, g.nprobe

        def local(idx_local: FrozenIndex, q) -> SearchResult:
            # strip the leading shard axis (size 1 per shard)
            sq = jax.tree_util.tree_map(
                lambda a: a[0], (idx_local.box_lo, idx_local.box_hi,
                                 idx_local.offsets, idx_local.data,
                                 idx_local.ids, idx_local.row_norms))
            lidx = dataclasses.replace(
                idx_local, box_lo=sq[0], box_hi=sq[1], offsets=sq[2],
                data=sq[3], ids=sq[4], row_norms=sq[5])
            # search_impl, not search: an inner jit under shard_map
            # miscompiles the refinement loop on jax 0.4.x.
            res = search_impl(
                lidx, q, k, delta=delta, epsilon=epsilon,
                nprobe=nprobe, visit_batch=visit_batch,
                sync_axes=tuple(axes) if sync_bsf else ())
            # gather per-shard top-k along a new leading axis and merge
            all_d = jax.lax.all_gather(res.dists, axes[-1], tiled=False)
            all_i = jax.lax.all_gather(res.ids, axes[-1], tiled=False)
            if len(axes) > 1:
                for ax in axes[:-1]:
                    all_d = jax.lax.all_gather(all_d, ax, tiled=False)
                    all_i = jax.lax.all_gather(all_i, ax, tiled=False)
                all_d = all_d.reshape(-1, b, k)
                all_i = all_i.reshape(-1, b, k)
            md = all_d.transpose(1, 0, 2).reshape(b, -1)
            mi = all_i.transpose(1, 0, 2).reshape(b, -1)
            sd, si = jax.lax.sort((md, mi), num_keys=1)
            leaves = jax.lax.psum(res.leaves_visited, axes)
            rows = jax.lax.psum(res.rows_scanned, axes)
            lbs = jax.lax.psum(res.lb_computed, axes)
            return SearchResult(sd[:, :k], si[:, :k], leaves, rows, lbs)

        out_specs = SearchResult(P(), P(), P(), P(), P())
        # The shard_map'ed fn is called EAGERLY on purpose: on jax
        # 0.4.x, putting this under jax.jit (inner OR outer) miscompiles
        # the refinement while_loop — verified wrong neighbors on
        # 0.4.37; eager execution is correct. Reusing the same wrapped
        # callable via _query_fns still avoids per-call closure
        # rebuilding and retracing.
        fn = compat.shard_map(
            local, mesh=self.mesh, in_specs=in_specs,
            out_specs=out_specs, check=False,
        )
        self._query_fns[cache_key] = fn
        return fn(idx, queries)
