"""DistributedSearchEngine — the paper's methods at pod scale.

The collection is range-sharded over the mesh's data-parallel axes; each
shard owns a FrozenIndex over its rows (ids stay global) plus the GLOBAL
distance histogram and global N, so per-shard r_delta matches the
single-node semantics. A query batch is replicated to all shards, each
runs the batched Algorithm 2 locally (shard_map), and per-shard top-k
rows are merged with an all-gather + static sort.

Guarantee preservation under sharding (docs/PERF.md §6): every global true
r-th NN lives in some shard where it ranks <= r locally; the local
guarantee bounds that shard's reported r-th by (1+eps) x local true r-th
<= (1+eps) x global true r-th, and the merged r-th best across shards
only improves — so exact/epsilon/delta-epsilon transfer. For delta<1 the
per-shard stopping radius uses the global N, making each shard's early
stop conservative w.r.t. the global distribution.

Fault tolerance: the frozen artifact checkpoints via train/checkpoint.py
like any pytree; straggler mitigation degrades the guarantee to
ng(nprobe) under a deadline — the taxonomy is the mitigation (paper
Fig. 8 shows the first bsf is already near-exact). Since PR 8 the
out-of-core path is fault-tolerant end to end (docs/FAULT.md): shards
are served by CONCURRENT owners (a worker pool streaming results into
the topk_merge_unique fold as they land — the merge is a commutative
(d, id)-lex selection, so completion order cannot change the answer),
``build(replicas=R)`` persists R copies of every shard store with
round-robin owner assignment, a failed/timed-out attempt retries with
capped exponential backoff and fails over to the next copy
(serve/fault.py: RetryPolicy + CircuitBreaker), and a shard lost past
every copy degrades the answer honestly — the query completes over
the surviving shards and OocStats reports ``degraded`` /
``shards_lost`` / ``effective_delta`` with delta recomputed from the
global distance histogram mass the missing rows own
(core.guarantees.effective_delta_after_loss).
"""

from __future__ import annotations

import dataclasses
import os
import shutil
import threading
import warnings
from concurrent.futures import ThreadPoolExecutor, as_completed
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat, obs
from repro.kernels import ops
from repro.obs import OocStats

from .guarantees import Guarantee
from .histogram import DistanceHistogram, build_histogram
from .index import FrozenIndex
from .indexes import dstree, isax, vafile
from .search import SearchResult, search_impl


class QueryResult(NamedTuple):
    """What :meth:`DistributedEngine.query` returns: the SearchResult
    fields plus the per-query :class:`OocStats` traveling WITH the
    answer. Stats used to be published through the mutable
    ``engine.last_ooc_stats`` field, which misattributes them the
    moment two ``query()`` calls run concurrently (the continuous-
    batching serving front has one in flight per lane) — so the field
    is gone and the ``engine-stats`` analysis rule keeps it gone
    (docs/ANALYSIS.md). ``stats`` is None on the resident shard_map
    path (no I/O to account) and an aggregated OocStats on the
    out-of-core path (per-shard schemas under ``.stats.shards``,
    degradation triple when shards were lost — docs/FAULT.md)."""

    dists: jax.Array           # [B, k] Euclidean distances, ascending
    ids: jax.Array             # [B, k] global row ids (-1 = missing)
    leaves_visited: jax.Array  # [B] int32, summed over shards
    rows_scanned: jax.Array    # [B] int32, summed over shards
    lb_computed: jax.Array     # scalar int32
    stats: Optional[OocStats] = None

_BUILDERS = {
    "isax2+": isax.build,
    "dstree": dstree.build,
    "va+file": vafile.build,
}


def _pad_to(arr: np.ndarray, target: int, fill) -> np.ndarray:
    if arr.shape[0] == target:
        return arr
    pad = np.full((target - arr.shape[0],) + arr.shape[1:], fill,
                  arr.dtype)
    return np.concatenate([arr, pad], axis=0)


def _discover_replicas(spill_dir: str, shard_dirs: Tuple[str, ...]
                       ) -> Tuple[Tuple[str, ...], ...]:
    """Per shard: (primary, *replica copies) found on disk. Replicas
    live under spill_dir/replicas/rN/shard_NNNN — deliberately NOT
    top-level shard_* names, which open_spill would mis-discover as
    independent shards."""
    rep_root = os.path.join(spill_dir, "replicas")
    rdirs = sorted(os.listdir(rep_root)) \
        if os.path.isdir(rep_root) else []
    out = []
    for d in shard_dirs:
        name = os.path.basename(d)
        copies = [d]
        for rd in rdirs:
            cand = os.path.join(rep_root, rd, name)
            if os.path.isdir(cand):
                copies.append(cand)
        out.append(tuple(copies))
    return tuple(out)


@dataclasses.dataclass
class DistributedEngine:
    mesh: Optional[Mesh]  # None for an OOC-only engine (open_spill)
    axes: Tuple[str, ...] = ("data",)
    method: str = "dstree"
    stacked: Optional[FrozenIndex] = None  # leading shard axis on arrays
    shard_dirs: Optional[Tuple[str, ...]] = None  # spilled store dirs
    # explicit shard count for a MESH-FREE engine (mesh=None +
    # build(keep_resident=False): multi-shard OOC serving without any
    # device mesh — the single-process stand-in for per-host shard
    # ownership); ignored when a mesh is set
    shards: Optional[int] = None
    # per shard: every on-disk copy of its store, PRIMARY FIRST
    # (build(replicas=R) / open_spill discovery); the failover loop
    # rotates the attempt order per shard for round-robin ownership
    shard_replica_dirs: Optional[Tuple[Tuple[str, ...], ...]] = None
    # jitted query fns keyed by (k, guarantee, batch shape, ...): the
    # shard_map body closes over those values, so a fresh closure per
    # call would defeat jit's compile cache. Lock-free on purpose:
    # dict get/set are GIL-atomic and two threads racing to build the
    # same key produce interchangeable callables (last one wins)
    _query_fns: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    # out-of-core serving state: per-shard LeafStore handles + warm
    # device leaf caches, opened lazily on the first OOC query and
    # reused across queries (the serving regime)
    _stores: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    _shard_caches: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    # serializes _stores/_shard_caches mutation against concurrent
    # shard owners and close(); per-shard search runs OUTSIDE it
    _ooc_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)
    # per shard-store-copy serving locks: CONCURRENT query() calls
    # (one per serving lane) share the warm per-copy DeviceLeafCache,
    # whose slot pool is only consistent for one query at a time (a
    # second query's get_slots may evict a slot the first is about to
    # gather) — so one query's use of one copy is one critical
    # section. Distinct shards/copies still serve fully in parallel;
    # lock order is copy lock -> _ooc_lock -> cache._lock (acyclic,
    # asserted by the lockorder stress test)
    _copy_locks: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    # persistent per-(shard, copy) circuit breaker (serve/fault.py),
    # created lazily on the first fault-tolerant OOC query
    _breaker: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def n_shards(self) -> int:
        if self.mesh is None:
            if self.shards is not None:
                return int(self.shards)
            return len(self.shard_dirs) if self.shard_dirs else 1
        shape = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        out = 1
        for a in self.axes:
            out *= shape[a]
        return out

    @classmethod
    def open_spill(cls, spill_dir: str, *, mesh: Optional[Mesh] = None,
                   axes: Tuple[str, ...] = ("data",),
                   method: str = "dstree") -> "DistributedEngine":
        """Open an engine over an existing ``build(spill_dir=...)``
        artifact WITHOUT loading any shard into HBM — the serving path
        for collections larger than device memory (multi-host: each
        host opens the shards it owns). ``query`` auto-detects the
        missing resident index and serves out-of-core. Replica copies
        persisted by ``build(replicas=R)`` (spill_dir/replicas/rN/
        shard_NNNN) are discovered too and arm failover."""
        shard_dirs = tuple(sorted(
            os.path.join(spill_dir, d) for d in os.listdir(spill_dir)
            if d.startswith("shard_")))
        if not shard_dirs:
            raise ValueError(f"no shard_* stores under {spill_dir!r}")
        eng = cls(mesh=mesh, axes=tuple(axes), method=method)
        eng.shard_dirs = shard_dirs
        eng.shard_replica_dirs = _discover_replicas(spill_dir,
                                                    shard_dirs)
        return eng

    # ------------------------------------------------------------------
    def build(self, data: np.ndarray, key=None,
              spill_dir: Optional[str] = None, codec: str = "f32",
              keep_resident: bool = True, replicas: int = 1,
              **params):
        """Shard rows, build per-shard indexes (embarrassingly parallel
        on hosts), stack and device_put with the shard axis mapped onto
        the mesh axes.

        ``spill_dir`` additionally persists every shard as an on-disk
        store artifact (spill_dir/shard_NNNN, global ids and global
        n_total preserved) so shards can be served out-of-core — since
        PR 4 directly by :meth:`query` (auto-detected, or forced with
        ``ooc=True``), the path toward collections larger than pod
        HBM. ``codec`` selects each shard's leaf payload encoding
        ("f32"/"bf16"/"pq", store format v2) — compressed spill shrinks
        every shard's bytes-read in the out-of-core serving path.
        ``keep_resident=False`` (requires ``spill_dir``) skips stacking
        the shards into HBM entirely: the engine holds only the spilled
        stores and every query runs the OOC path — on a MESH-FREE
        engine (``mesh=None`` + ``shards=N``) this is the only legal
        mode, and the shard count comes from ``self.shards``.
        ``replicas=R`` persists R on-disk copies of every shard store
        (the primary plus R-1 byte-identical replicas under
        spill_dir/replicas/rN/ — no re-encode, so pq codebooks and
        leaf payloads match bit for bit) with round-robin owner
        assignment; a failed or timed-out shard attempt fails over to
        the next copy before the query degrades (docs/FAULT.md)."""
        if not keep_resident and spill_dir is None:
            raise ValueError("keep_resident=False requires spill_dir")
        if self.mesh is None and keep_resident:
            raise ValueError(
                "mesh-free engine (mesh=None) cannot hold a resident "
                "index: build with keep_resident=False + spill_dir")
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if replicas > 1 and spill_dir is None:
            raise ValueError("replicas > 1 requires spill_dir")
        key = key if key is not None else jax.random.PRNGKey(0)
        self._query_fns.clear()  # compiled against the previous index
        self.close()             # OOC state from the previous build
        n = data.shape[0]
        s = self.n_shards
        bounds = np.linspace(0, n, s + 1).astype(np.int64)
        sample = data[np.random.default_rng(0).choice(
            n, min(n, 100_000), replace=False)]
        hist = build_histogram(sample, key)  # GLOBAL histogram
        builder = _BUILDERS[self.method]

        shards = []
        spill_dirs = []
        for si in range(s):
            lo, hi = bounds[si], bounds[si + 1]
            idx = builder(data[lo:hi], hist=hist, key=key, **params)
            # re-map ids to global, keep global n_total for r_delta
            ids = np.asarray(idx.ids)
            ids = np.where(ids >= 0, ids + lo, -1)
            idx = dataclasses.replace(
                idx, ids=jnp.asarray(ids, jnp.int32), n_total=n)
            if spill_dir is not None:
                d = os.path.join(spill_dir, f"shard_{si:04d}")
                spill_dirs.append(idx.save(d, codec=codec))
                # replica copies are byte-identical file copies of the
                # saved store (same ids, histogram, pq codebook), laid
                # out under replicas/rN so open_spill's shard_*
                # discovery cannot mistake them for extra shards
                for rep in range(1, replicas):
                    rd = os.path.join(spill_dir, "replicas",
                                      f"r{rep}", f"shard_{si:04d}")
                    if os.path.isdir(rd):
                        shutil.rmtree(rd)
                    shutil.copytree(spill_dirs[-1], rd)
            if keep_resident:
                shards.append(idx)  # else: spilled, drop the HBM copy
        self.shard_dirs = tuple(spill_dirs) if spill_dirs else None
        self.shard_replica_dirs = _discover_replicas(
            spill_dir, self.shard_dirs) if spill_dirs else None
        if not keep_resident:
            self.stacked = None
            return self

        # uniform static metadata + padded array shapes across shards
        max_leafL = max(sh.num_leaves for sh in shards)
        max_rows = max(sh.data.shape[0] for sh in shards)
        max_leaf = max(sh.max_leaf for sh in shards)
        arrs = {"box_lo": [], "box_hi": [], "offsets": [], "data": [],
                "ids": [], "row_norms": []}
        for sh in shards:
            L = sh.num_leaves
            off = np.asarray(sh.offsets)
            # pad leaves with empty extents pointing at the end
            offp = np.concatenate(
                [off, np.full(max_leafL - L, off[-1], off.dtype)])
            arrs["box_lo"].append(_pad_to(
                np.asarray(sh.box_lo), max_leafL, np.float32(1e30)))
            arrs["box_hi"].append(_pad_to(
                np.asarray(sh.box_hi), max_leafL, np.float32(1e30)))
            arrs["offsets"].append(offp)
            arrs["data"].append(_pad_to(
                np.asarray(sh.data), max_rows, np.float32(0)))
            arrs["ids"].append(_pad_to(
                np.asarray(sh.ids), max_rows, np.int64(-1)))
            # padding rows are all-zero, so norm 0 keeps the cache
            # consistent with the padded data
            arrs["row_norms"].append(_pad_to(
                np.asarray(sh.row_norms), max_rows, np.float32(0)))

        spec0 = P(self.axes if len(self.axes) > 1 else self.axes[0])

        def put(x):
            return jax.device_put(
                x, NamedSharding(self.mesh, spec0))

        base = shards[0]
        self.stacked = FrozenIndex(
            box_lo=put(jnp.asarray(np.stack(arrs["box_lo"]))),
            box_hi=put(jnp.asarray(np.stack(arrs["box_hi"]))),
            offsets=put(jnp.asarray(np.stack(arrs["offsets"]),
                                    jnp.int32)),
            data=put(jnp.asarray(np.stack(arrs["data"]))),
            ids=put(jnp.asarray(np.stack(arrs["ids"]), jnp.int32)),
            row_norms=put(jnp.asarray(np.stack(arrs["row_norms"]),
                                      jnp.float32)),
            weights=jax.device_put(
                base.weights, NamedSharding(self.mesh, P())),
            hist=DistanceHistogram(
                edges=jax.device_put(
                    hist.edges, NamedSharding(self.mesh, P())),
                cdf=jax.device_put(
                    hist.cdf, NamedSharding(self.mesh, P())),
            ),
            kind=base.kind, summary=base.summary,
            n_summary=base.n_summary, max_leaf=max_leaf,
            n_total=n, series_len=base.series_len,
        )
        return self

    # ------------------------------------------------------------------
    def query(
        self, queries, k: int, g: Guarantee = Guarantee(),
        visit_batch: int = 1, sync_bsf: bool = False,
        ooc: Optional[bool] = None, ooc_opts: Optional[dict] = None,
    ) -> QueryResult:
        """Batched distributed k-NN with the requested guarantee.

        Spill-built shards are first class: when the engine has no
        HBM-resident index (``build(keep_resident=False)`` or
        :meth:`open_spill`) the query runs the out-of-core path —
        detected automatically, or forced with ``ooc=True`` on an
        engine that holds both. ``ooc_opts`` forwards out-of-core
        knobs (share_gathers / cache_leaves / prefetch /
        prefetch_depth / rerank / frontier) to search_ooc, plus the
        fault-tolerance knobs the engine consumes itself
        (docs/FAULT.md): ``fault`` (a repro.fault.FaultInjector),
        ``retry`` (a serve.fault.RetryPolicy), ``workers`` (shard
        owner pool width; default min(n_shards, 8), 1 = the
        sequential fold). Per-shard caches stay warm across queries.

        Re-entrant: concurrent ``query()`` calls (the continuous-
        batching serving lanes each keep one in flight) return answers
        bit-exact to serial execution — per-query state travels on the
        returned :class:`QueryResult` (``.stats`` carries the
        aggregate per-shard OocStats, including the degradation block
        when a shard was lost past its replicas), and shared warm
        caches are serialized per shard copy so two queries never
        interleave on one slot pool."""
        if ooc is None:
            ooc = self.stacked is None and self.shard_dirs is not None
        if ooc:
            if sync_bsf:
                # the sequential per-shard host loops do not exchange
                # a running best-so-far yet (each shard prunes against
                # its own) — seeding shard i+1's pool from the fold of
                # shards 0..i is the ROADMAP follow-up; until then the
                # flag must not be silently swallowed
                warnings.warn(
                    "sync_bsf is not supported on the out-of-core "
                    "path: shards are searched without cross-shard "
                    "best-so-far exchange (results are identical, "
                    "bytes-read/leaves-visited are not tightened).",
                    UserWarning, stacklevel=2)
            return self._query_ooc(queries, k, g, visit_batch,
                                   dict(ooc_opts or {}))
        assert self.stacked is not None, "build() first"
        idx = self.stacked
        b = queries.shape[0]
        cache_key = (k, g.delta, g.epsilon, g.nprobe, visit_batch,
                     sync_bsf, b, queries.shape[-1])
        cached = self._query_fns.get(cache_key)
        if cached is not None:
            return self._run_resident(cached, idx, queries, k, b)
        axes = self.axes
        spec_shard = P(axes if len(axes) > 1 else axes[0])
        in_specs = (
            FrozenIndex(
                box_lo=spec_shard, box_hi=spec_shard, offsets=spec_shard,
                data=spec_shard, ids=spec_shard, weights=P(),
                hist=DistanceHistogram(edges=P(), cdf=P()),
                kind=idx.kind, summary=idx.summary,
                n_summary=idx.n_summary, max_leaf=idx.max_leaf,
                n_total=idx.n_total, series_len=idx.series_len,
                row_norms=spec_shard,
            ),
            P(),  # queries replicated
        )

        delta, epsilon, nprobe = g.delta, g.epsilon, g.nprobe

        def local(idx_local: FrozenIndex, q) -> SearchResult:
            # strip the leading shard axis (size 1 per shard)
            sq = jax.tree_util.tree_map(
                lambda a: a[0], (idx_local.box_lo, idx_local.box_hi,
                                 idx_local.offsets, idx_local.data,
                                 idx_local.ids, idx_local.row_norms))
            lidx = dataclasses.replace(
                idx_local, box_lo=sq[0], box_hi=sq[1], offsets=sq[2],
                data=sq[3], ids=sq[4], row_norms=sq[5])
            # search_impl, not search: an inner jit under shard_map
            # miscompiles the refinement loop on jax 0.4.x.
            # repro: allow[jax-while-shard-map] deliberate: this closure is dispatched ONLY through the eager compat.shard_map below (never under jit) precisely because of the 0.4.37 miscompile — ROADMAP pin notes
            res = search_impl(
                lidx, q, k, delta=delta, epsilon=epsilon,
                nprobe=nprobe, visit_batch=visit_batch,
                sync_axes=tuple(axes) if sync_bsf else ())
            # gather per-shard top-k along a new leading axis and merge
            all_d = jax.lax.all_gather(res.dists, axes[-1], tiled=False)
            all_i = jax.lax.all_gather(res.ids, axes[-1], tiled=False)
            if len(axes) > 1:
                for ax in axes[:-1]:
                    all_d = jax.lax.all_gather(all_d, ax, tiled=False)
                    all_i = jax.lax.all_gather(all_i, ax, tiled=False)
                all_d = all_d.reshape(-1, b, k)
                all_i = all_i.reshape(-1, b, k)
            md = all_d.transpose(1, 0, 2).reshape(b, -1)
            mi = all_i.transpose(1, 0, 2).reshape(b, -1)
            sd, si = jax.lax.sort((md, mi), num_keys=1)
            leaves = jax.lax.psum(res.leaves_visited, axes)
            rows = jax.lax.psum(res.rows_scanned, axes)
            lbs = jax.lax.psum(res.lb_computed, axes)
            return SearchResult(sd[:, :k], si[:, :k], leaves, rows, lbs)

        out_specs = SearchResult(P(), P(), P(), P(), P())
        # The shard_map'ed fn is called EAGERLY on purpose: on jax
        # 0.4.x, putting this under jax.jit (inner OR outer) miscompiles
        # the refinement while_loop — verified wrong neighbors on
        # 0.4.37; eager execution is correct. Reusing the same wrapped
        # callable via _query_fns still avoids per-call closure
        # rebuilding and retracing.
        fn = compat.shard_map(
            local, mesh=self.mesh, in_specs=in_specs,
            out_specs=out_specs, check=False,
        )
        self._query_fns[cache_key] = fn
        return self._run_resident(fn, idx, queries, k, b)

    def _run_resident(self, fn, idx, queries, k: int, b: int
                      ) -> QueryResult:
        """Dispatch the (cached) shard_map'ed resident query, wrapped
        in a span when tracing is enabled. The block_until_ready is
        span-only: the untraced path keeps its async dispatch. The
        resident path has no I/O to account, so ``stats`` is None —
        thread-safe by construction (eager shard_map dispatch touches
        no per-query engine state)."""
        if not obs.enabled():
            return QueryResult(*fn(idx, queries))
        with obs.span("engine.query", path="resident", lanes=b, k=k,
                      shards=self.n_shards) as sp:
            res = fn(idx, queries)
            jax.block_until_ready(res.dists)
            sp.set(leaves_visited=int(np.asarray(
                       res.leaves_visited).sum()),
                   rows_scanned=int(np.asarray(res.rows_scanned).sum()))
        return QueryResult(*res)

    # ------------------------------------------------------------------
    def _copy_lock(self, d: str) -> threading.RLock:
        """The serving lock for one shard store copy (lazily created
        under ``_ooc_lock``, held for a whole per-shard search):
        concurrent queries — serving lanes each keep one in flight —
        serialize per copy because the warm DeviceLeafCache slot pool
        is single-query state (another query's get_slots may evict a
        slot this one is about to gather from, which would break the
        bit-exact-vs-serial contract). Within one query the shard
        owners touch DISTINCT copies, so PR 8's concurrent fold is
        unaffected."""
        with self._ooc_lock:
            lk = self._copy_locks.get(d)
            if lk is None:
                lk = self._copy_locks[d] = threading.RLock()
            return lk

    def _store(self, d: str):
        """The (lazily opened, cached) store for one shard copy —
        lock-guarded: concurrent shard owners open their stores in
        parallel on the first query."""
        with self._ooc_lock:
            store = self._stores.get(d)
        if store is not None:
            return store
        from repro.store import load_index
        store = load_index(d, resident="summaries")
        with self._ooc_lock:
            # a concurrent open of the same dir (close() racing a
            # query) keeps the first registered handle
            return self._stores.setdefault(d, store)

    def _shard_cache(self, d: str, store, need_leaves: int,
                     cache_leaves: Optional[int], *,
                     prefetch_depth: int, prefetch: bool):
        """The shard copy's persistent warm cache + prefetcher,
        re-validated per query: a cache whose capacity cannot pin this
        query's per-iteration working set (b * visit_batch leaves —
        batch sizes vary per guarantee group in the serving front) is
        retired and rebuilt larger, and the prefetcher thread persists
        with the cache instead of being spawned and joined per query
        (its staging depth grows with the requested lookahead).

        Runs under ``_ooc_lock`` end to end: owners touch DISTINCT
        dirs so the serialization costs nothing on the steady path,
        and it makes the dict re-validation atomic against a
        concurrent ``close()`` (mid-query close retires the cache;
        this query keeps its own reference and finishes on it)."""
        from repro.store import DeviceLeafCache, LeafPrefetcher

        need = max(int(need_leaves), 1)
        with self._ooc_lock:
            cache = self._shard_caches.get(d)
            if cache is not None \
                    and cache.capacity < min(need,
                                             max(store.num_leaves, 1)):
                if cache.prefetcher is not None:
                    cache.prefetcher.close()
                    cache.prefetcher = None
                cache = None
            if cache is None:
                cap = cache_leaves if cache_leaves is not None \
                    else max(store.num_leaves // 8, 1)
                cap = min(max(cap, need), max(store.num_leaves, 1))
                cache = DeviceLeafCache(store, cap)
                self._shard_caches[d] = cache
            else:
                # warm CONTENTS persist across queries (the serving
                # regime); counters reset so QueryResult.stats reports
                # this query's bytes, not the cache's lifetime
                cache.reset_counters()
            if prefetch:
                depth = max(2, prefetch_depth + 1)
                if cache.prefetcher is not None \
                        and cache.prefetcher.depth < depth:
                    cache.prefetcher.close()
                    cache.prefetcher = None
                if cache.prefetcher is None:
                    cache.prefetcher = LeafPrefetcher(store,
                                                      depth=depth)
        return cache

    def close(self) -> None:
        """Release out-of-core serving state: stop every per-shard
        prefetcher thread and drop the warm caches/stores. build()
        calls this before rebuilding; harmless on a resident-only
        engine. Idempotent and thread-safe: state is snapshotted and
        detached under the lock, prefetcher threads are joined outside
        it (a query in flight keeps its own cache reference and falls
        back to demand reads once its prefetcher stops)."""
        with self._ooc_lock:
            caches = list(self._shard_caches.values())
            self._shard_caches.clear()
            self._stores.clear()
        for cache in caches:
            if cache.prefetcher is not None:
                cache.prefetcher.close()
                cache.prefetcher = None

    def _query_ooc(self, queries, k: int, g: Guarantee,
                   visit_batch: int, opts: dict) -> QueryResult:
        """Serve the query batch from the spilled shard stores:
        CONCURRENT shard owners (one worker per shard, pool width
        ``workers``) each drive the host refinement loop over their
        store — the SAME shared core search_impl traces
        (core/refine.py) — and stream their answers into a cross-shard
        ``ops.topk_merge_unique`` fold on this thread as they land.
        Completion order cannot change the answer: the merge is a
        commutative, associative (d, id)-lex selection over globally
        disjoint ids, so the fold equals the sequential fold bit for
        bit. Parity with the resident shard_map path: per-shard
        results are bit-exact to the resident per-shard search for
        lossless codecs (tests/test_store.py) and both merges select
        the k smallest distances — so ids AND dists match the resident
        engine answer bit-for-bit (modulo cross-shard ties, which
        (d, id)-lex ordering resolves deterministically). Guarantee
        preservation is the same argument as the shard_map path
        (module docstring): every shard's answer satisfies the local
        guarantee against the GLOBAL histogram/n_total persisted in
        its store, and the merge only improves each rank.

        Fault tolerance (docs/FAULT.md): each shard serve runs under
        serve/fault.serve_shard_with_failover — retries with capped
        backoff across the shard's store copies (round-robin owner
        first), per-attempt deadlines checked cooperatively inside the
        host loop, a persistent circuit breaker skipping copies that
        keep failing. A shard lost past every copy degrades the
        answer instead of failing the query: the fold completes over
        the survivors and the returned ``QueryResult.stats`` carries
        ``degraded`` / ``shards_lost`` / ``effective_delta`` with delta recomputed
        from the global histogram mass the missing rows own
        (core.guarantees.effective_delta_after_loss)."""
        from repro.serve import fault as sfault
        from repro.store.ooc import search_ooc

        from .guarantees import effective_delta_after_loss

        if not self.shard_dirs:
            raise ValueError(
                "no spilled shards: build(spill_dir=...) or "
                "open_spill() first")
        g.validate()
        qj = jnp.asarray(queries)
        b = qj.shape[0]
        cache_leaves = opts.pop("cache_leaves", None)
        injector = opts.pop("fault", None)
        policy = opts.pop("retry", None) or sfault.RetryPolicy()
        n_sh = len(self.shard_dirs)
        workers = int(opts.pop("workers", 0) or min(n_sh, 8))
        prefetch_depth = int(opts.get("prefetch_depth", 1))
        prefetch = bool(opts.get("prefetch", True))
        replica_dirs = self.shard_replica_dirs \
            or tuple((d,) for d in self.shard_dirs)
        with self._ooc_lock:
            if self._breaker is None:
                self._breaker = sfault.CircuitBreaker()
            breaker = self._breaker

        def attempt_for(si):
            def attempt(d, fctx):
                # one query's use of one copy is one critical section
                # (_copy_lock): cache revalidation, counter window and
                # slot-pool occupancy stay single-query even when
                # serving lanes race on the same shard. An attempt
                # that waits out its deadline here fails on its first
                # in-loop check and falls over to another copy — a
                # DIFFERENT lock — instead of queueing forever.
                with self._copy_lock(d):
                    store = self._store(d)
                    cache = self._shard_cache(
                        d, store, b * visit_batch, cache_leaves,
                        prefetch_depth=prefetch_depth,
                        prefetch=prefetch)
                    # the child ooc.query span carries the shard's
                    # bytes_read attr — one subtree level owns each
                    # numeric attr, so QueryProfile.total() never
                    # double-counts. Worker-thread spans root their
                    # own per-thread subtree (obs/trace.py).
                    with obs.span("engine.shard", shard=si,
                                  copy=fctx.replica):
                        return search_ooc(
                            store, qj, k, delta=g.delta,
                            epsilon=g.epsilon, nprobe=g.nprobe,
                            visit_batch=visit_batch, cache=cache,
                            fault=fctx, **opts)
            return attempt

        def serve_one(si):
            copies = replica_dirs[si]
            # round-robin ownership: shard si's owner is copy
            # (si % R); failover walks the remaining copies in order
            order = tuple(copies[(si + j) % len(copies)]
                          for j in range(len(copies)))
            return sfault.serve_shard_with_failover(
                attempt_for(si), shard=si, replica_dirs=order,
                policy=policy, breaker=breaker, injector=injector)

        top_d = jnp.full((b, k), jnp.inf, jnp.float32)
        top_i = jnp.full((b, k), -1, jnp.int32)
        leaves = np.zeros(b, np.int64)
        rows = np.zeros(b, np.int64)
        lbs = 0
        per_shard = []
        infos = []
        lost = []
        with obs.span("engine.query", path="ooc", lanes=b, k=k,
                      shards=n_sh, workers=workers) as root:

            def fold(si, served):
                out, info = served
                out.stats.retries = info.retries
                out.stats.failovers = info.failovers
                obs.REGISTRY.counter(
                    "engine.shard.bytes_read", shard=str(si)).inc(
                        out.stats.bytes_read)
                r = out.result
                # shard dists are already sqrt'd like the resident
                # merge operands; ids are globally disjoint across
                # shards, so the unique-merge's dedup is a no-op — it
                # is used for its (d, id)-lex selection and its
                # explicit precondition
                nonlocal top_d, top_i, lbs, leaves, rows
                top_d, top_i = ops.topk_merge_unique(
                    r.dists, r.ids, top_d, top_i)
                leaves += np.asarray(r.leaves_visited, np.int64)
                rows += np.asarray(r.rows_scanned, np.int64)
                lbs += int(r.lb_computed)
                per_shard.append(out.stats)
                infos.append(info)

            if workers <= 1 or n_sh == 1:
                # sequential fold: no worker threads, spans nest
                # under this root exactly as before PR 8
                for si in range(n_sh):
                    try:
                        served = serve_one(si)
                    except sfault.ShardLost:
                        lost.append(si)
                        continue
                    fold(si, served)
            else:
                with ThreadPoolExecutor(
                        max_workers=min(workers, n_sh),
                        thread_name_prefix="shard-owner") as ex:
                    futs = {ex.submit(serve_one, si): si
                            for si in range(n_sh)}
                    for fut in as_completed(futs):
                        si = futs[fut]
                        try:
                            served = fut.result()
                        except sfault.ShardLost:
                            lost.append(si)
                            continue
                        fold(si, served)
            if len(lost) == n_sh:
                raise sfault.ShardLost(
                    -1, RuntimeError(
                        f"every shard lost ({sorted(lost)}): no "
                        "surviving answer to degrade to"))
            stats = OocStats.aggregate(per_shard)
            stats.effective_delta = float(g.delta)
            if lost:
                self._degrade(stats, sorted(lost), infos, top_d, k, g,
                              effective_delta_after_loss)
                root.set(degraded=True, shards_lost=stats.shards_lost,
                         effective_delta=stats.effective_delta)
            root.set(bytes_read_total=stats.bytes_read,
                     iterations=stats.iterations)
        return QueryResult(
            dists=top_d, ids=top_i,
            leaves_visited=jnp.asarray(leaves, jnp.int32),
            rows_scanned=jnp.asarray(rows, jnp.int32),
            lb_computed=jnp.int32(lbs),
            stats=stats,
        )

    def _degrade(self, stats: OocStats, lost, infos, top_d, k: int,
                 g: Guarantee, effective_delta_after_loss) -> None:
        """Downgrade the answer's guarantee honestly after shard loss:
        count the rows the fold never saw (global n_total minus the
        survivors' real rows — robust to uneven range-sharding) and
        recompute delta from the global histogram mass those rows own
        at each lane's surviving kth distance. The result is a
        delta-epsilon guarantee whatever the request was — exact and
        epsilon claims cannot survive unseen rows."""
        surv = [self._store(i.served_dir) for i in infos]
        n_total = int(surv[0].resident.n_total)
        n_seen = sum(
            int((np.asarray(s.resident.ids) >= 0).sum()) for s in surv)
        n_lost = max(n_total - n_seen, 0)
        stats.degraded = True
        stats.shards_lost = len(lost)
        stats.effective_delta = effective_delta_after_loss(
            surv[0].resident.hist, np.asarray(top_d[:, k - 1]),
            n_lost, delta=g.delta, epsilon=g.epsilon)
        obs.REGISTRY.counter("engine.degraded_queries").inc()
        obs.REGISTRY.counter("engine.shards_lost").inc(len(lost))
        warnings.warn(
            f"shards {lost} lost past retries and replicas: answer "
            f"degraded to delta-epsilon with effective_delta="
            f"{stats.effective_delta:.3g} over {n_lost} unseen rows "
            "(docs/FAULT.md)", UserWarning, stacklevel=4)
