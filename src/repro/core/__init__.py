"""Hydra-JAX core: the paper's similarity-search contribution.

Public API:
    guarantees   — the taxonomy (exact / ng / epsilon / delta-epsilon)
    index        — FrozenIndex artifact
    search       — batched Algorithm 1/2 (+ brute_force yardstick)
    indexes      — isax / dstree / vafile / imi / graph / srs builders
    histogram    — F(.) estimation and r_delta
    metrics      — Avg_Recall / MAP / MRE
    engine       — DistributedSearchEngine (shard_map over the mesh)
    spec         — IndexSpec / StoreSpec typed build+serve surface
"""

from . import guarantees, histogram, index, metrics, search, spec
from .guarantees import (EXACT, Guarantee, delta_epsilon, epsilon,
                         exact, joint_n_total, ng)
from .index import FrozenIndex
from .search import (SearchResult, brute_force, search_ooc,
                     search_with_guarantee)
from .spec import APIDeprecationWarning, IndexSpec, StoreSpec

__all__ = [
    "guarantees", "histogram", "index", "metrics", "search", "spec",
    "EXACT", "Guarantee", "delta_epsilon", "epsilon", "exact",
    "joint_n_total", "ng", "FrozenIndex", "SearchResult",
    "brute_force", "search_ooc", "search_with_guarantee",
    "APIDeprecationWarning", "IndexSpec", "StoreSpec",
]
