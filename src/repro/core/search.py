"""Batched, TPU-native Algorithm 1 / Algorithm 2 (the paper's §3.2.3).

Semantics are the paper's exactly; the execution strategy is the TPU
adaptation (docs/PERF.md):

  1. lower-bound every leaf in one vectorized pass (box_mindist kernel);
  2. LAZY leaf frontier -> per-query visit order (the priority-queue
     order): instead of a full [B, L] argsort, partially select only the
     first F ranks with lax.top_k and refill each lane's frontier from
     the remaining lb pool when it runs low. The refill threshold is the
     last consumed (lb, leaf-id) pair, so every refill selects exactly
     the lexicographic successors — the emitted order is provably the
     stable argsort order (globally non-decreasing lb, Algorithm 2's
     correctness condition) while per-query sort work scales with ranks
     VISITED, not with L.
  3. `lax.while_loop` over visit ranks: each iteration every active query
     lane gathers its next `visit_batch` leaves, computes true distances
     (fused L2 with squared row norms cached at freeze time), merges
     into its running sorted top-k via O(k) partial-selection merges
     (kernels/ops.py topk_merge*), and evaluates the stopping predicate
         next_lb > bsf/(1+eps)            [Alg.2 line 10/20 pruning]
       | bsf <= (1+eps) * r_delta         [Alg.2 line 16 early stop]
       | visited >= nprobe                [ng-approximate]
       | exhausted                        [scanned everything]
     where bsf is the kth-best true distance (k-NN generalization [42]).

Since PR 4 the loop BODY is not defined here: every parity-critical
piece — frontier tick/advance, candidate layout, duplicate-leaf
masking, the codec-dispatched score+merge step, and the stopping
predicates — lives once in core/refine.py, and this while_loop simply
traces those shared functions over a :class:`refine.ResidentSource`
(the HBM residency). store/ooc.py drives the SAME functions from its
host loop over the cached-store sources, so in-memory/out-of-core
parity holds by construction.

Guarantees: with nprobe=None this is exact for (delta=1, eps=0),
epsilon-approximate for (1, eps), delta-epsilon otherwise — identical to
Algorithm 2 because leaves are visited in non-decreasing lb order and the
predicates match (frontier proof in docs/PERF.md). All comparisons run
in squared-distance space to avoid sqrt in the loop.

`visit_batch > 1` amortizes loop overhead (essential for VA+file where a
"leaf" is a single series); it can only visit *more* than strictly
necessary, never fewer, so guarantees are preserved.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import refine
from .guarantees import Guarantee
from .histogram import r_delta
from .index import FrozenIndex

default_frontier = refine.default_frontier


class SearchResult(NamedTuple):
    dists: jax.Array          # [B, k] Euclidean distances, ascending
    ids: jax.Array            # [B, k] original row ids (-1 = missing)
    leaves_visited: jax.Array  # [B] int32
    rows_scanned: jax.Array    # [B] int32 raw series touched
    lb_computed: jax.Array     # scalar int32 (= L, the filter pass size)


def search_impl(
    index: FrozenIndex,
    queries: jax.Array,  # [B, n]
    k: int,
    *,
    delta: float = 1.0,
    epsilon: float = 0.0,
    nprobe: Optional[int] = None,
    visit_batch: int = 1,
    force_pallas: bool = False,
    sync_axes: tuple = (),
    share_gathers: bool = False,
    frontier: Optional[int] = None,
    dead: Optional[jax.Array] = None,
    n_override: Optional[int] = None,
) -> SearchResult:
    """Batched Algorithm 2 body (see module docstring for semantics).

    share_gathers (cooperative query batching, §Perf beyond-paper):
    every iteration's gathered rows are scored against ALL query lanes
    (one MXU matmul) instead of only the lane that requested them.
    Extra candidates can only improve a lane's top-k, so every
    guarantee is preserved, while each lane's best-so-far tightens from
    the whole batch's I/O — the per-query bytes drop measurably
    (docs/PERF.md §4). Raises arithmetic intensity from ~0.5 to
    ~0.5*B flops/byte on the refinement stream.

    sync_axes (inside shard_map only): exchange the best-so-far with
    `pmin` over the given mesh axes every iteration, so pruning uses the
    GLOBAL kth-best. Exactness-preserving: the global kth-best distance
    is <= every shard's local kth-best, so the stop threshold only
    tightens; any locally-unvisited candidate with lb above it cannot
    enter the global top-k (§Perf beyond-paper optimization — the
    collective analogue of the paper's shared bsf). Loop continuation
    becomes a global flag carried in-state so shards iterate in
    lockstep (collectives inside the body, none in cond).

    frontier: lazy leaf-frontier width F (ranks partially selected per
    refill; None -> default_frontier). Any width yields the SAME visit
    order — the stable argsort order — it only tunes how much lookahead
    each refill materializes.

    dead / n_override (mutable tier, docs/INGEST.md): ``dead`` is a
    [n_padded] bool tombstone mask over this index's row positions —
    masked rows score inf in refine_step and never surface.
    ``n_override`` substitutes the LIVE joint row count for
    ``index.n_total`` in the delta-guarantee radius r_delta (inserts
    must RAISE N: r_delta shrinks with N, so a stale smaller N would be
    anti-conservative)."""
    b, n = queries.shape
    L = index.num_leaves
    v = visit_batch

    src = refine.ResidentSource(index, force_pallas=force_pallas)
    ctx = src.query_ctx(queries)
    if dead is not None:
        ctx = ctx._replace(dead=dead)

    # ---- filter: lower bound to every leaf ----
    lb_sq = refine.leaf_lower_bounds(index, queries,
                                     force_pallas=force_pallas)  # [B, L]

    # lazy frontier: refilled window by window inside the loop body
    # (never a full [B, L] argsort)
    F = default_frontier(L, v) if frontier is None \
        else min(max(int(frontier), v + 1), L)

    eps_mult = jnp.float32((1.0 + epsilon) ** 2)
    rd = r_delta(index.hist, delta,
                 index.n_total if n_override is None else n_override)
    rd_sq = (rd * rd).astype(jnp.float32)
    max_rank = L if nprobe is None else min(nprobe, L)

    class State(NamedTuple):
        rank: jax.Array       # [B] next visit rank
        top_d: jax.Array      # [B, k] squared, ascending
        top_i: jax.Array      # [B, k]
        active: jax.Array     # [B] bool
        leaves: jax.Array     # [B]
        rows: jax.Array       # [B]
        go: jax.Array         # scalar bool: any shard still active
        fr: refine.FrontierState

    init = State(
        rank=jnp.zeros((b,), jnp.int32),
        top_d=jnp.full((b, k), refine.INF),
        top_i=jnp.full((b, k), -1, jnp.int32),
        active=jnp.ones((b,), bool),
        leaves=jnp.zeros((b,), jnp.int32),
        rows=jnp.zeros((b,), jnp.int32),
        go=jnp.asarray(True),
        fr=refine.frontier_init(b, F),
    )

    def cond(s: State):
        return s.go

    def body(s: State) -> State:
        fr, leaf = refine.frontier_tick(s.fr, lb_sq, s.active,
                                        v=v, lookahead=v)

        # ranks to visit this iteration: [B, V]
        rk = s.rank[:, None] + jnp.arange(v)[None, :]
        in_range = rk < max_rank
        ok = in_range & s.active[:, None]
        g = src.gather(leaf, ok)
        if share_gathers:
            # all lanes' rows pooled; every query scores every row.
            # Copies of a leaf pooled twice THIS iteration are masked
            # (coop_mask) so pool ids stay distinct — the
            # topk_merge_unique/coop_score_select precondition; dedup
            # across ITERATIONS happens in the merge.
            pool_valid = refine.coop_mask(leaf, ok, g.valid)
            top_d, top_i = src.score(ctx, g, pool_valid,
                                     s.top_d, s.top_i, share=True)
        else:
            top_d, top_i = src.score(ctx, g, g.valid,
                                     s.top_d, s.top_i, share=False)

        visited = jnp.sum(in_range, axis=1).astype(jnp.int32)
        leaves = s.leaves + jnp.where(s.active, visited, 0)
        rows_c = s.rows + jnp.where(
            s.active, jnp.sum(g.valid, axis=1).astype(jnp.int32), 0)

        fr, next_lb = refine.frontier_advance(fr, s.active, v=v)
        rank_next = jnp.minimum(s.rank + v, max_rank)
        exhausted = rank_next >= max_rank
        bsf = top_d[:, k - 1]
        if sync_axes:
            bsf = jax.lax.pmin(bsf, sync_axes)  # global kth-best
        stop = refine.stop_mask(next_lb, exhausted, bsf, eps_mult, rd_sq)
        active = s.active & ~stop
        go = jnp.any(active)
        if sync_axes:
            go = jax.lax.pmax(go.astype(jnp.int32), sync_axes) > 0
        return State(rank_next, top_d, top_i, active, leaves, rows_c,
                     go, fr)

    final = jax.lax.while_loop(cond, body, init)
    return SearchResult(
        dists=jnp.sqrt(final.top_d),
        ids=final.top_i,
        leaves_visited=final.leaves,
        rows_scanned=final.rows,
        lb_computed=jnp.int32(L),
    )


# Jitted core of the public entry point. Callers already inside a
# shard_map region must use `search_impl` directly: nesting this jit
# under shard_map miscompiles the while_loop on jax 0.4.x (the
# refinement loop exits after ~2 iterations with check_rep=False),
# observed on 0.4.37.
_search_jit = jax.jit(
    search_impl,
    static_argnames=("k", "nprobe", "visit_batch", "force_pallas",
                     "sync_axes", "share_gathers", "frontier",
                     "n_override"),
)


def search(index: FrozenIndex, queries: jax.Array, k: int,
           g: Optional[Guarantee] = None, **kw) -> SearchResult:
    """Public jitted entry point (`search_impl` semantics). The
    guarantee is ONE object — ``g=Guarantee(...)`` (constructors in
    core.guarantees: exact/epsilon/delta_epsilon/ng); the historical
    loose ``delta=``/``epsilon=``/``nprobe=`` kwargs still work for one
    release via a shim that emits APIDeprecationWarning (an error under
    scripts/verify.sh, and the ``guarantee-kwargs`` analysis rule fails
    in-repo callers). When span tracing is enabled (repro.obs) the call
    is wrapped in a ``core.search`` span — blocking on the result so
    the span measures the device work; untraced calls keep jit's async
    dispatch and pay only this one flag check."""
    from repro import obs
    from .spec import coerce_guarantee

    g = coerce_guarantee(g, kw, caller="search")
    kw.update(delta=g.delta, epsilon=g.epsilon, nprobe=g.nprobe)
    if not obs.enabled():
        return _search_jit(index, queries, k, **kw)
    with obs.span("core.search", lanes=queries.shape[0], k=k,
                  leaves=index.num_leaves) as sp:
        res = _search_jit(index, queries, k, **kw)
        jax.block_until_ready(res.dists)
        sp.set(leaves_visited=int(jnp.sum(res.leaves_visited)),
               rows_scanned=int(jnp.sum(res.rows_scanned)))
    return res


def search_ooc(store, queries: jax.Array, k: int,
               g: Optional[Guarantee] = None, **kw):
    """Out-of-core Algorithm 2 over a LeafStore (see repro.store):
    identical visit order and stopping predicates to :func:`search` —
    only residency differs, so every guarantee transfers (exception:
    the lossy codec="pq" payload supports the epsilon/delta-epsilon
    checks via its exact re-rank but not exact epsilon=0 search, and
    warns if asked). The guarantee is one ``g=Guarantee(...)`` object
    (loose delta/epsilon/nprobe kwargs are the deprecated shim, as in
    :func:`search`); also accepts visit_batch plus
    cache/cache_leaves/prefetch, share_gathers (cooperative scoring,
    as in :func:`search_impl`), frontier (lazy visit-order window
    width), prefetch_depth (speculative lookahead in visit windows),
    rerank (codec="pq" exact re-rank pool multiplier), and
    dead/n_override (tombstones + live-N joint guarantee,
    docs/INGEST.md); returns OocResult(result=SearchResult,
    stats=OocStats)."""
    from repro.store.ooc import search_ooc as impl

    return impl(store, queries, k, g, **kw)


def search_with_guarantee(
    index: FrozenIndex, queries: jax.Array, k: int, g: Guarantee, **kw
) -> SearchResult:
    return search(index, queries, k, g, **kw)


def brute_force(queries: jax.Array, data: jax.Array, k: int,
                **kw) -> SearchResult:
    """Exact linear-scan yardstick (fused L2 + top-k)."""
    from repro.kernels import ops

    d, i = ops.l2_topk(queries, data, k, **kw)
    b = queries.shape[0]
    n = data.shape[0]
    return SearchResult(
        dists=jnp.sqrt(jnp.maximum(d, 0.0)),
        ids=i.astype(jnp.int32),
        leaves_visited=jnp.full((b,), n, jnp.int32),
        rows_scanned=jnp.full((b,), n, jnp.int32),
        lb_computed=jnp.int32(0),
    )
