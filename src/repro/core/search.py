"""Batched, TPU-native Algorithm 1 / Algorithm 2 (the paper's §3.2.3).

Semantics are the paper's exactly; the execution strategy is the TPU
adaptation of DESIGN.md §3:

  1. lower-bound every leaf in one vectorized pass (box_mindist kernel);
  2. argsort -> per-query leaf visit order (the priority-queue order);
  3. `lax.while_loop` over visit ranks: each iteration every active query
     lane gathers its next `visit_batch` leaves, computes true distances
     (fused L2), merges into its running sorted top-k, and evaluates the
     stopping predicate
         next_lb > bsf/(1+eps)            [Alg.2 line 10/20 pruning]
       | bsf <= (1+eps) * r_delta         [Alg.2 line 16 early stop]
       | visited >= nprobe                [ng-approximate]
       | exhausted                        [scanned everything]
     where bsf is the kth-best true distance (k-NN generalization [42]).

Guarantees: with nprobe=None this is exact for (delta=1, eps=0),
epsilon-approximate for (1, eps), delta-epsilon otherwise — identical to
Algorithm 2 because leaves are visited in non-decreasing lb order and the
predicates match (proof sketch in DESIGN.md §3). All comparisons run in
squared-distance space to avoid sqrt in the loop.

`visit_batch > 1` amortizes loop overhead (essential for VA+file where a
"leaf" is a single series); it can only visit *more* than strictly
necessary, never fewer, so guarantees are preserved.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .guarantees import Guarantee
from .histogram import r_delta
from .index import FrozenIndex

INF = jnp.float32(jnp.inf)


class SearchResult(NamedTuple):
    dists: jax.Array          # [B, k] Euclidean distances, ascending
    ids: jax.Array            # [B, k] original row ids (-1 = missing)
    leaves_visited: jax.Array  # [B] int32
    rows_scanned: jax.Array    # [B] int32 raw series touched
    lb_computed: jax.Array     # scalar int32 (= L, the filter pass size)


def _batched_sq_l2(q: jax.Array, rows: jax.Array) -> jax.Array:
    """q [B, n], rows [B, M, n] -> [B, M] f32 squared distances."""
    qf = q.astype(jnp.float32)
    rf = rows.astype(jnp.float32)
    qn = jnp.sum(qf * qf, axis=-1)[:, None]
    rn = jnp.sum(rf * rf, axis=-1)
    cross = jnp.einsum("bn,bmn->bm", qf, rf,
                       preferred_element_type=jnp.float32)
    return jnp.maximum(qn - 2.0 * cross + rn, 0.0)


def search_impl(
    index: FrozenIndex,
    queries: jax.Array,  # [B, n]
    k: int,
    *,
    delta: float = 1.0,
    epsilon: float = 0.0,
    nprobe: Optional[int] = None,
    visit_batch: int = 1,
    force_pallas: bool = False,
    sync_axes: tuple = (),
    share_gathers: bool = False,
) -> SearchResult:
    """Batched Algorithm 2 body (see module docstring for semantics).

    share_gathers (cooperative query batching, §Perf beyond-paper):
    every iteration's gathered rows are scored against ALL query lanes
    (one MXU matmul) instead of only the lane that requested them.
    Extra candidates can only improve a lane's top-k, so every
    guarantee is preserved, while each lane's best-so-far tightens from
    the whole batch's I/O — the per-query bytes drop measurably
    (EXPERIMENTS.md §Perf). Raises arithmetic intensity from ~0.5 to
    ~0.5*B flops/byte on the refinement stream.

    sync_axes (inside shard_map only): exchange the best-so-far with
    `pmin` over the given mesh axes every iteration, so pruning uses the
    GLOBAL kth-best. Exactness-preserving: the global kth-best distance
    is <= every shard's local kth-best, so the stop threshold only
    tightens; any locally-unvisited candidate with lb above it cannot
    enter the global top-k (§Perf beyond-paper optimization — the
    collective analogue of the paper's shared bsf). Loop continuation
    becomes a global flag carried in-state so shards iterate in
    lockstep (collectives inside the body, none in cond)."""
    b, n = queries.shape
    L = index.num_leaves
    m = index.max_leaf
    v = visit_batch
    npad = index.data.shape[0]

    # ---- filter: lower bound to every leaf, visit order ----
    q_sum = index.summarize_queries(queries)
    lb_sq = ops.box_mindist(
        q_sum, index.box_lo, index.box_hi, index.weights,
        force_pallas=force_pallas,
    )  # [B, L] squared
    order = jnp.argsort(lb_sq, axis=1)
    lb_sorted = jnp.take_along_axis(lb_sq, order, axis=1)

    eps_mult = jnp.float32((1.0 + epsilon) ** 2)
    rd = r_delta(index.hist, delta, index.n_total)
    rd_sq = (rd * rd).astype(jnp.float32)
    max_rank = L if nprobe is None else min(nprobe, L)

    qf = queries.astype(jnp.float32)

    class State(NamedTuple):
        rank: jax.Array       # [B] next visit rank
        top_d: jax.Array      # [B, k] squared, ascending
        top_i: jax.Array      # [B, k]
        active: jax.Array     # [B] bool
        leaves: jax.Array     # [B]
        rows: jax.Array       # [B]
        go: jax.Array         # scalar bool: any shard still active

    init = State(
        rank=jnp.zeros((b,), jnp.int32),
        top_d=jnp.full((b, k), INF),
        top_i=jnp.full((b, k), -1, jnp.int32),
        active=jnp.ones((b,), bool),
        leaves=jnp.zeros((b,), jnp.int32),
        rows=jnp.zeros((b,), jnp.int32),
        go=jnp.asarray(True),
    )

    lane = jnp.arange(b)

    def cond(s: State):
        return s.go

    def body(s: State) -> State:
        # ranks to visit this iteration: [B, V]
        rk = s.rank[:, None] + jnp.arange(v)[None, :]
        in_range = rk < max_rank
        rk_c = jnp.minimum(rk, L - 1)
        leaf = jnp.take_along_axis(order, rk_c, axis=1)  # [B, V]
        start = index.offsets[leaf]          # [B, V]
        end = index.offsets[leaf + 1]
        pos = jnp.arange(m)[None, None, :]
        idx = start[:, :, None] + pos        # [B, V, M]
        valid = (idx < end[:, :, None]) & in_range[:, :, None] \
            & s.active[:, None, None]
        idx = jnp.minimum(idx, npad - 1).reshape(b, v * m)
        if share_gathers:
            # all lanes' rows pooled; every query scores every row
            flat = idx.reshape(b * v * m)
            rows = index.data[flat]          # [B*V*M, n]
            fvalid = valid.reshape(b * v * m)
            cand_ids = jnp.where(fvalid, index.ids[flat], -1)
            d = jnp.maximum(
                jnp.sum(qf * qf, 1)[:, None]
                - 2.0 * (qf @ rows.astype(jnp.float32).T)
                + jnp.sum(rows.astype(jnp.float32) ** 2, 1)[None, :],
                0.0)
            d = jnp.where(fvalid[None, :], d, INF)
            # dedup merge: a leaf pooled at two iterations is scored
            # twice for every lane; plain topk_merge would both return
            # duplicate ids and shrink the kth-best below the true kth
            # distinct distance (stopping too early)
            top_d, top_i = ops.topk_merge_unique(
                d, jnp.broadcast_to(cand_ids, (b, b * v * m)),
                s.top_d, s.top_i)
        else:
            rows = index.data[idx]           # [B, V*M, n]
            cand_ids = jnp.where(valid.reshape(b, v * m),
                                 index.ids[idx], -1)
            d = _batched_sq_l2(qf, rows)
            d = jnp.where(valid.reshape(b, v * m), d, INF)
            top_d, top_i = ops.topk_merge(d, cand_ids, s.top_d, s.top_i)

        visited = jnp.sum(in_range, axis=1).astype(jnp.int32)
        leaves = s.leaves + jnp.where(s.active, visited, 0)
        rows_c = s.rows + jnp.where(
            s.active, jnp.sum(valid, axis=(1, 2)).astype(jnp.int32), 0)

        rank_next = jnp.minimum(s.rank + v, max_rank)
        exhausted = rank_next >= max_rank
        next_lb = jnp.where(
            exhausted, INF,
            lb_sorted[lane, jnp.minimum(rank_next, L - 1)],
        )
        bsf = top_d[:, k - 1]
        if sync_axes:
            bsf = jax.lax.pmin(bsf, sync_axes)  # global kth-best
        stop = (next_lb * eps_mult > bsf) \
            | (bsf <= eps_mult * rd_sq) \
            | exhausted
        active = s.active & ~stop
        go = jnp.any(active)
        if sync_axes:
            go = jax.lax.pmax(go.astype(jnp.int32), sync_axes) > 0
        return State(rank_next, top_d, top_i, active, leaves, rows_c, go)

    final = jax.lax.while_loop(cond, body, init)
    return SearchResult(
        dists=jnp.sqrt(final.top_d),
        ids=final.top_i,
        leaves_visited=final.leaves,
        rows_scanned=final.rows,
        lb_computed=jnp.int32(L),
    )


# Public jitted entry point. Callers already inside a shard_map region
# must use `search_impl` directly: nesting this jit under shard_map
# miscompiles the while_loop on jax 0.4.x (the refinement loop exits
# after ~2 iterations with check_rep=False), observed on 0.4.37.
search = jax.jit(
    search_impl,
    static_argnames=("k", "nprobe", "visit_batch", "force_pallas",
                     "sync_axes", "share_gathers"),
)


def search_ooc(store, queries: jax.Array, k: int, **kw):
    """Out-of-core Algorithm 2 over a LeafStore (see repro.store):
    identical visit order and stopping predicates to :func:`search` —
    only residency differs, so every guarantee transfers (exception:
    the lossy codec="pq" payload supports the epsilon/delta-epsilon
    checks via its exact re-rank but not exact epsilon=0 search, and
    warns if asked). Accepts
    delta/epsilon/nprobe/visit_batch plus cache/cache_leaves/prefetch,
    share_gathers (cooperative scoring, as in :func:`search_impl`) and
    rerank (codec="pq" exact re-rank pool multiplier); returns
    OocResult(result=SearchResult, stats={bytes_read, hit_rate,
    codec, ...})."""
    from repro.store.ooc import search_ooc as impl

    return impl(store, queries, k, **kw)


def search_with_guarantee(
    index: FrozenIndex, queries: jax.Array, k: int, g: Guarantee, **kw
) -> SearchResult:
    g.validate()
    return search(index, queries, k, delta=g.delta, epsilon=g.epsilon,
                  nprobe=g.nprobe, **kw)


def brute_force(queries: jax.Array, data: jax.Array, k: int,
                **kw) -> SearchResult:
    """Exact linear-scan yardstick (fused L2 + top-k)."""
    d, i = ops.l2_topk(queries, data, k, **kw)
    b = queries.shape[0]
    n = data.shape[0]
    return SearchResult(
        dists=jnp.sqrt(jnp.maximum(d, 0.0)),
        ids=i.astype(jnp.int32),
        leaves_visited=jnp.full((b,), n, jnp.int32),
        rows_scanned=jnp.full((b,), n, jnp.int32),
        lb_computed=jnp.int32(0),
    )
