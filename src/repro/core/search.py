"""Batched, TPU-native Algorithm 1 / Algorithm 2 (the paper's §3.2.3).

Semantics are the paper's exactly; the execution strategy is the TPU
adaptation (docs/PERF.md):

  1. lower-bound every leaf in one vectorized pass (box_mindist kernel);
  2. LAZY leaf frontier -> per-query visit order (the priority-queue
     order): instead of a full [B, L] argsort, partially select only the
     first F ranks with lax.top_k and refill each lane's frontier from
     the remaining lb pool when it runs low. The refill threshold is the
     last consumed (lb, leaf-id) pair, so every refill selects exactly
     the lexicographic successors — the emitted order is provably the
     stable argsort order (globally non-decreasing lb, Algorithm 2's
     correctness condition) while per-query sort work scales with ranks
     VISITED, not with L.
  3. `lax.while_loop` over visit ranks: each iteration every active query
     lane gathers its next `visit_batch` leaves, computes true distances
     (fused L2 with squared row norms cached at freeze time), merges
     into its running sorted top-k via O(k) partial-selection merges
     (kernels/ops.py topk_merge*), and evaluates the stopping predicate
         next_lb > bsf/(1+eps)            [Alg.2 line 10/20 pruning]
       | bsf <= (1+eps) * r_delta         [Alg.2 line 16 early stop]
       | visited >= nprobe                [ng-approximate]
       | exhausted                        [scanned everything]
     where bsf is the kth-best true distance (k-NN generalization [42]).

Guarantees: with nprobe=None this is exact for (delta=1, eps=0),
epsilon-approximate for (1, eps), delta-epsilon otherwise — identical to
Algorithm 2 because leaves are visited in non-decreasing lb order and the
predicates match (frontier proof in docs/PERF.md). All comparisons run
in squared-distance space to avoid sqrt in the loop.

`visit_batch > 1` amortizes loop overhead (essential for VA+file where a
"leaf" is a single series); it can only visit *more* than strictly
necessary, never fewer, so guarantees are preserved.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .guarantees import Guarantee
from .histogram import r_delta
from .index import FrozenIndex

INF = jnp.float32(jnp.inf)


class SearchResult(NamedTuple):
    dists: jax.Array          # [B, k] Euclidean distances, ascending
    ids: jax.Array            # [B, k] original row ids (-1 = missing)
    leaves_visited: jax.Array  # [B] int32
    rows_scanned: jax.Array    # [B] int32 raw series touched
    lb_computed: jax.Array     # scalar int32 (= L, the filter pass size)


def default_frontier(num_leaves: int, visit_batch: int) -> int:
    """Default lazy-frontier width: a few refill-free batches of
    lookahead (covering this iteration's visits, the next_lb probe and
    the prefetch window) without approaching the full leaf count."""
    return min(num_leaves, max(64, 4 * visit_batch))


def frontier_select(lb_sq: jax.Array, thr_lb: jax.Array,
                    thr_id: jax.Array, f: int) -> tuple:
    """Partially select each lane's next ``f`` visit ranks: the
    lexicographic (lb, leaf-id) successors of the lane's threshold
    pair (thr = (-1, -1) selects the first window). lax.top_k breaks
    lb ties by lower leaf id — the stable argsort tie order — so
    chaining selections reproduces the full sorted visit order exactly
    (Algorithm 2's non-decreasing-lb condition; docs/PERF.md §2).

    THE visit-order primitive: search_impl's in-loop refill and
    store.ooc's host refill both call this one function, so the
    bit-exact in-memory/OOC parity of the visit order holds by
    construction."""
    L = lb_sq.shape[1]
    iota = jnp.arange(L, dtype=jnp.int32)
    remaining = jnp.where(
        (lb_sq > thr_lb[:, None])
        | ((lb_sq == thr_lb[:, None])
           & (iota[None, :] > thr_id[:, None])),
        lb_sq, INF)
    nv, ni = jax.lax.top_k(-remaining, f)
    return -nv, ni


def dup_leaf_mask(leaf: jax.Array, ok: jax.Array) -> jax.Array:
    """[B, V] leaf ids + slot-usable mask -> [B, V] True where the slot
    repeats a leaf already pooled by an EARLIER usable slot this
    iteration. The cooperative paths mask those copies out before
    scoring, which (a) keeps ops.topk_merge_unique's distinct-id
    precondition and (b) changes nothing semantically — the copies
    carry bit-identical (d, id) pairs.

    Shared by search_impl (device) and search_ooc's host loop (tiny
    [B, V] operands) so both cooperative pools stay identical by
    construction. dup[i] = exists j < i with leaf_j == leaf_i and
    ok[j]; computed in O(BV log BV): sort slots by (leaf, ok-first
    rank), find each leaf group's leader (its minimal-position usable
    slot), and a slot is a duplicate iff that leader is usable and
    strictly earlier."""
    bv = leaf.shape[0] * leaf.shape[1]
    fl = jnp.asarray(leaf, jnp.int32).reshape(bv)
    fo = jnp.asarray(ok).reshape(bv)
    posv = jnp.arange(bv, dtype=jnp.int32)
    rank = jnp.where(fo, posv, posv + bv)  # usable slots sort first
    leaf_s, _, pos_s, ok_s = jax.lax.sort(
        (fl, rank, posv, fo.astype(jnp.int32)), num_keys=2)
    t = jnp.arange(bv, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), leaf_s[1:] != leaf_s[:-1]])
    start_idx = jax.lax.cummax(jnp.where(is_start, t, 0))
    leader_ok = ok_s[start_idx] > 0
    leader_pos = pos_s[start_idx]
    dup_s = leader_ok & (leader_pos < pos_s)
    dup = jnp.zeros((bv,), bool).at[pos_s].set(dup_s)
    return dup.reshape(leaf.shape)


def search_impl(
    index: FrozenIndex,
    queries: jax.Array,  # [B, n]
    k: int,
    *,
    delta: float = 1.0,
    epsilon: float = 0.0,
    nprobe: Optional[int] = None,
    visit_batch: int = 1,
    force_pallas: bool = False,
    sync_axes: tuple = (),
    share_gathers: bool = False,
    frontier: Optional[int] = None,
) -> SearchResult:
    """Batched Algorithm 2 body (see module docstring for semantics).

    share_gathers (cooperative query batching, §Perf beyond-paper):
    every iteration's gathered rows are scored against ALL query lanes
    (one MXU matmul) instead of only the lane that requested them.
    Extra candidates can only improve a lane's top-k, so every
    guarantee is preserved, while each lane's best-so-far tightens from
    the whole batch's I/O — the per-query bytes drop measurably
    (docs/PERF.md §4). Raises arithmetic intensity from ~0.5 to
    ~0.5*B flops/byte on the refinement stream.

    sync_axes (inside shard_map only): exchange the best-so-far with
    `pmin` over the given mesh axes every iteration, so pruning uses the
    GLOBAL kth-best. Exactness-preserving: the global kth-best distance
    is <= every shard's local kth-best, so the stop threshold only
    tightens; any locally-unvisited candidate with lb above it cannot
    enter the global top-k (§Perf beyond-paper optimization — the
    collective analogue of the paper's shared bsf). Loop continuation
    becomes a global flag carried in-state so shards iterate in
    lockstep (collectives inside the body, none in cond).

    frontier: lazy leaf-frontier width F (ranks partially selected per
    refill; None -> default_frontier). Any width yields the SAME visit
    order — the stable argsort order — it only tunes how much lookahead
    each refill materializes."""
    b, n = queries.shape
    L = index.num_leaves
    m = index.max_leaf
    v = visit_batch
    npad = index.data.shape[0]

    # ---- filter: lower bound to every leaf ----
    q_sum = index.summarize_queries(queries)
    lb_sq = ops.box_mindist(
        q_sum, index.box_lo, index.box_hi, index.weights,
        force_pallas=force_pallas,
    )  # [B, L] squared

    # lazy frontier: the first F ranks of the visit order, refilled in
    # the loop body when a lane runs low (never a full [B, L] argsort)
    F = default_frontier(L, v) if frontier is None \
        else min(max(int(frontier), v + 1), L)
    fr_lb0, fr_id0 = frontier_select(
        lb_sq, jnp.full((b,), -1.0, jnp.float32),
        jnp.full((b,), -1, jnp.int32), F)

    eps_mult = jnp.float32((1.0 + epsilon) ** 2)
    rd = r_delta(index.hist, delta, index.n_total)
    rd_sq = (rd * rd).astype(jnp.float32)
    max_rank = L if nprobe is None else min(nprobe, L)

    qf = queries.astype(jnp.float32)
    norms = index.row_norms if index.row_norms is not None \
        else ops.row_sq_norms(index.data)

    class State(NamedTuple):
        rank: jax.Array       # [B] next visit rank
        top_d: jax.Array      # [B, k] squared, ascending
        top_i: jax.Array      # [B, k]
        active: jax.Array     # [B] bool
        leaves: jax.Array     # [B]
        rows: jax.Array       # [B]
        go: jax.Array         # scalar bool: any shard still active
        fr_lb: jax.Array      # [B, F] frontier lbs (rank window)
        fr_id: jax.Array      # [B, F] frontier leaf ids
        fpos: jax.Array       # [B] next unconsumed frontier position
        thr_lb: jax.Array     # [B] last consumed lb (refill threshold)
        thr_id: jax.Array     # [B] last consumed leaf id

    init = State(
        rank=jnp.zeros((b,), jnp.int32),
        top_d=jnp.full((b, k), INF),
        top_i=jnp.full((b, k), -1, jnp.int32),
        active=jnp.ones((b,), bool),
        leaves=jnp.zeros((b,), jnp.int32),
        rows=jnp.zeros((b,), jnp.int32),
        go=jnp.asarray(True),
        fr_lb=fr_lb0,
        fr_id=fr_id0,
        fpos=jnp.zeros((b,), jnp.int32),
        thr_lb=jnp.full((b,), -1.0, jnp.float32),
        thr_id=jnp.full((b,), -1, jnp.int32),
    )

    lane = jnp.arange(b)

    def cond(s: State):
        return s.go

    def refill_frontier(fr_lb, fr_id, fpos, thr_lb, thr_id, need):
        """Refilling lanes get the F lexicographic (lb, leaf-id)
        successors of their threshold — exactly ranks [rank, rank+F)
        of the stable argsort order (frontier_select)."""
        nv, ni = frontier_select(lb_sq, thr_lb, thr_id, F)
        sel = need[:, None]
        return (jnp.where(sel, nv, fr_lb),
                jnp.where(sel, ni, fr_id),
                jnp.where(need, 0, fpos))

    def body(s: State) -> State:
        # refill exhausted frontiers first (rare: amortized once per
        # floor(F/v) iterations per lane; skipped entirely via cond
        # when no lane needs it)
        need = s.active & (s.fpos > F - 1 - v)
        fr_lb, fr_id, fpos = jax.lax.cond(
            jnp.any(need),
            lambda a: refill_frontier(*a),
            lambda a: a[:3],
            (s.fr_lb, s.fr_id, s.fpos, s.thr_lb, s.thr_id, need),
        )

        # ranks to visit this iteration: [B, V]
        rk = s.rank[:, None] + jnp.arange(v)[None, :]
        in_range = rk < max_rank
        ppos = jnp.minimum(fpos[:, None] + jnp.arange(v)[None, :], F - 1)
        leaf = jnp.take_along_axis(fr_id, ppos, axis=1)  # [B, V]
        start = index.offsets[leaf]          # [B, V]
        end = index.offsets[leaf + 1]
        pos = jnp.arange(m)[None, None, :]
        idx = start[:, :, None] + pos        # [B, V, M]
        valid = (idx < end[:, :, None]) & in_range[:, :, None] \
            & s.active[:, None, None]
        idx = jnp.minimum(idx, npad - 1).reshape(b, v * m)
        if share_gathers:
            # all lanes' rows pooled; every query scores every row.
            # Copies of a leaf pooled twice THIS iteration are masked
            # (dup_leaf_mask) so pool ids stay distinct — the
            # topk_merge_unique/coop_score_select precondition; dedup
            # across ITERATIONS happens in the merge.
            flat = idx.reshape(b * v * m)
            rows = index.data[flat]          # [B*V*M, n]
            slot_ok = in_range & s.active[:, None]
            dup = dup_leaf_mask(leaf, slot_ok)
            fvalid = (valid & ~dup[:, :, None]).reshape(b * v * m)
            cand_ids = jnp.where(fvalid, index.ids[flat], -1)
            # fused score+select: candidates for the dedup merge are
            # chosen per lane without materializing [B, B*V*M] on TPU
            sel_d, sel_i = ops.coop_score_select(
                qf, rows, norms[flat], cand_ids,
                min(2 * k, b * v * m), force_pallas=force_pallas)
            top_d, top_i = ops.dedup_merge_topk(
                sel_d, sel_i, s.top_d, s.top_i)
        else:
            rows = index.data[idx]           # [B, V*M, n]
            cand_ids = jnp.where(valid.reshape(b, v * m),
                                 index.ids[idx], -1)
            d = ops.sq_l2(qf, rows, norms[idx])
            d = jnp.where(valid.reshape(b, v * m), d, INF)
            top_d, top_i = ops.topk_merge(d, cand_ids, s.top_d, s.top_i)

        visited = jnp.sum(in_range, axis=1).astype(jnp.int32)
        leaves = s.leaves + jnp.where(s.active, visited, 0)
        rows_c = s.rows + jnp.where(
            s.active, jnp.sum(valid, axis=(1, 2)).astype(jnp.int32), 0)

        rank_next = jnp.minimum(s.rank + v, max_rank)
        exhausted = rank_next >= max_rank
        next_lb = jnp.where(
            exhausted, INF,
            jnp.take_along_axis(
                fr_lb, jnp.minimum(fpos + v, F - 1)[:, None], axis=1,
            )[:, 0],
        )
        bsf = top_d[:, k - 1]
        if sync_axes:
            bsf = jax.lax.pmin(bsf, sync_axes)  # global kth-best
        stop = (next_lb * eps_mult > bsf) \
            | (bsf <= eps_mult * rd_sq) \
            | exhausted
        active = s.active & ~stop
        go = jnp.any(active)
        if sync_axes:
            go = jax.lax.pmax(go.astype(jnp.int32), sync_axes) > 0

        # refill threshold <- last rank consumed this iteration
        last = jnp.minimum(fpos + v - 1, F - 1)[:, None]
        thr_lb = jnp.where(
            s.active, jnp.take_along_axis(fr_lb, last, axis=1)[:, 0],
            s.thr_lb)
        thr_id = jnp.where(
            s.active, jnp.take_along_axis(fr_id, last, axis=1)[:, 0],
            s.thr_id)
        return State(rank_next, top_d, top_i, active, leaves, rows_c,
                     go, fr_lb, fr_id, fpos + v, thr_lb, thr_id)

    final = jax.lax.while_loop(cond, body, init)
    return SearchResult(
        dists=jnp.sqrt(final.top_d),
        ids=final.top_i,
        leaves_visited=final.leaves,
        rows_scanned=final.rows,
        lb_computed=jnp.int32(L),
    )


# Public jitted entry point. Callers already inside a shard_map region
# must use `search_impl` directly: nesting this jit under shard_map
# miscompiles the while_loop on jax 0.4.x (the refinement loop exits
# after ~2 iterations with check_rep=False), observed on 0.4.37.
search = jax.jit(
    search_impl,
    static_argnames=("k", "nprobe", "visit_batch", "force_pallas",
                     "sync_axes", "share_gathers", "frontier"),
)


def search_ooc(store, queries: jax.Array, k: int, **kw):
    """Out-of-core Algorithm 2 over a LeafStore (see repro.store):
    identical visit order and stopping predicates to :func:`search` —
    only residency differs, so every guarantee transfers (exception:
    the lossy codec="pq" payload supports the epsilon/delta-epsilon
    checks via its exact re-rank but not exact epsilon=0 search, and
    warns if asked). Accepts
    delta/epsilon/nprobe/visit_batch plus cache/cache_leaves/prefetch,
    share_gathers (cooperative scoring, as in :func:`search_impl`),
    frontier (lazy visit-order window width, as in :func:`search_impl`)
    and rerank (codec="pq" exact re-rank pool multiplier); returns
    OocResult(result=SearchResult, stats={bytes_read, hit_rate,
    codec, ...})."""
    from repro.store.ooc import search_ooc as impl

    return impl(store, queries, k, **kw)


def search_with_guarantee(
    index: FrozenIndex, queries: jax.Array, k: int, g: Guarantee, **kw
) -> SearchResult:
    g.validate()
    return search(index, queries, k, delta=g.delta, epsilon=g.epsilon,
                  nprobe=g.nprobe, **kw)


def brute_force(queries: jax.Array, data: jax.Array, k: int,
                **kw) -> SearchResult:
    """Exact linear-scan yardstick (fused L2 + top-k)."""
    d, i = ops.l2_topk(queries, data, k, **kw)
    b = queries.shape[0]
    n = data.shape[0]
    return SearchResult(
        dists=jnp.sqrt(jnp.maximum(d, 0.0)),
        ids=i.astype(jnp.int32),
        leaves_visited=jnp.full((b,), n, jnp.int32),
        rows_scanned=jnp.full((b,), n, jnp.int32),
        lb_computed=jnp.int32(0),
    )
