"""FrozenIndex: the searchable artifact shared by iSAX2+/DSTree/VA+file.

Every data-series index in the paper reduces, once built, to the same
searchable structure (docs/PERF.md §6): per-leaf summary-space *boxes* with
per-dim weights (the lower bound is a weighted box distance), leaf extents
over a leaf-contiguous permutation of the raw data, and the distance
histogram for r_delta. Trees differ only in how boxes/extents are chosen
at build time; search (core/search.py) is index-invariant, exactly like
the paper's Algorithm 1/2.

The dataclass is registered as a pytree (arrays = children, layout
metadata = aux) so it jits, shards (DistributedEngine stacks one per mesh
shard), and checkpoints like any other model state.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .histogram import DistanceHistogram
from .summaries import dft as dft_mod
from .summaries import eapca as eapca_mod
from .summaries import paa as paa_mod


@dataclasses.dataclass(frozen=True)
class FrozenIndex:
    # --- array children ---
    box_lo: jax.Array    # [L, D] summary-space box lower corners
    box_hi: jax.Array    # [L, D]
    weights: jax.Array   # [D] per-dim lb weights
    offsets: jax.Array   # [L+1] int32 leaf extents into the data rows
    data: jax.Array      # [Npad, n] raw series, leaf-contiguous
    ids: jax.Array       # [Npad] int32 original ids (-1 = padding)
    hist: DistanceHistogram
    # --- static metadata ---
    kind: str = dataclasses.field(metadata={"static": True})
    summary: str = dataclasses.field(metadata={"static": True})
    n_summary: int = dataclasses.field(metadata={"static": True})
    max_leaf: int = dataclasses.field(metadata={"static": True})
    n_total: int = dataclasses.field(metadata={"static": True})
    series_len: int = dataclasses.field(metadata={"static": True})
    # [Npad] f32 squared row norms of ``data``, cached at freeze time so
    # the refinement loop gathers |x|^2 instead of re-reducing the
    # gathered rows every iteration (docs/PERF.md). Optional: indexes
    # assembled without freeze_from_leaves fall back to a one-off
    # compute in search_impl.
    row_norms: Optional[jax.Array] = None

    @property
    def num_leaves(self) -> int:
        return self.box_lo.shape[0]

    def summarize_queries(self, q: jax.Array) -> jax.Array:
        """Apply this index's summarization to a query batch [B, n]."""
        if self.summary == "paa":
            return paa_mod.transform(q, self.n_summary)
        if self.summary == "eapca":
            return eapca_mod.transform(q, self.n_summary)
        if self.summary == "dft":
            return dft_mod.transform(q, self.n_summary)
        raise ValueError(self.summary)

    # --- out-of-core storage tier (repro.store) ---
    def save(self, directory: str, **kw) -> str:
        """Persist as an on-disk artifact (leaf-contiguous data.bin +
        sidecar); reload with :meth:`load`. ``codec`` in {"f32",
        "bf16", "pq"} selects the leaf payload encoding (store format
        v2 — see repro.store.layout); pq_* kwargs tune the codebook."""
        from repro.store import layout

        return layout.save_index(self, directory, **kw)

    @classmethod
    def load(cls, directory: str, resident: str = "full"):
        """resident="full" -> FrozenIndex (bit-exact round trip);
        resident="summaries" -> repro.store.LeafStore whose raw data
        stays on disk (serve with core.search.search_ooc)."""
        from repro.store import layout

        return layout.load_index(directory, resident=resident)


jax.tree_util.register_dataclass(
    FrozenIndex,
    data_fields=["box_lo", "box_hi", "weights", "offsets", "data", "ids",
                 "hist", "row_norms"],
    meta_fields=["kind", "summary", "n_summary", "max_leaf", "n_total",
                 "series_len"],
)


def freeze_from_leaves(
    data: np.ndarray,            # [N, n] original order
    leaf_members: list,          # list of int arrays (original row ids)
    box_lo: np.ndarray,          # [L, D]
    box_hi: np.ndarray,
    weights: np.ndarray,         # [D]
    hist: DistanceHistogram,
    *,
    kind: str,
    summary: str,
    n_summary: int,
    pad_multiple: int = 8,
    data_dtype=np.float32,
) -> FrozenIndex:
    """Assemble the device-side artifact from host-side build output.

    ``data_dtype=bfloat16`` halves the raw-data HBM footprint and read
    traffic of the refinement step (§Perf beyond-paper optimization);
    distances still accumulate in f32 — the ranking perturbation is
    measured in benchmarks/bench_best_methods.py."""
    n, series_len = data.shape
    sizes = np.array([len(m) for m in leaf_members], np.int64)
    offsets = np.zeros(len(leaf_members) + 1, np.int64)
    offsets[1:] = np.cumsum(sizes)
    perm = np.concatenate(leaf_members) if leaf_members else \
        np.zeros(0, np.int64)
    assert perm.shape[0] == n, (perm.shape, n)
    npad = int(np.ceil(max(n, 1) / pad_multiple) * pad_multiple)
    pdata = np.zeros((npad, series_len), np.float32)
    pdata[:n] = data[perm]
    if jnp.dtype(data_dtype) != jnp.float32:
        pdata = np.asarray(jnp.asarray(pdata, data_dtype))
    pids = np.full(npad, -1, np.int64)
    pids[:n] = perm
    dev_data = jnp.asarray(pdata, data_dtype)
    return FrozenIndex(
        box_lo=jnp.asarray(box_lo, jnp.float32),
        box_hi=jnp.asarray(box_hi, jnp.float32),
        weights=jnp.asarray(weights, jnp.float32),
        offsets=jnp.asarray(offsets, jnp.int32),
        data=dev_data,
        ids=jnp.asarray(pids, jnp.int32),
        hist=hist,
        row_norms=ops.row_sq_norms(dev_data),
        kind=kind,
        summary=summary,
        n_summary=n_summary,
        max_leaf=int(sizes.max()) if len(sizes) else 1,
        n_total=n,
        series_len=series_len,
    )
