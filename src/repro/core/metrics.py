"""Accuracy measures from the paper §4.1: Avg_Recall, MAP, MRE.

Definitions follow the paper exactly:
  Recall(Q)  = |returned ∩ true_kNN| / k
  AP(Q)      = (1/k) * sum_r P(Q, r) * rel(r), where P(Q, r) is precision
               at rank r and rel(r)=1 iff the r-th returned item is one of
               the k true neighbors.
  RE(Q)      = (1/k) * sum_r (d(Q, C_r) - d(Q, C*_r)) / d(Q, C*_r), the
               rank-paired relative error vs the exact r-th neighbor
               distance (zero-distance queries are excluded, as in the
               paper's footnote 5).
Workload aggregates are plain means over queries.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def _membership(returned_ids: jax.Array, true_ids: jax.Array) -> jax.Array:
    """rel [B, k]: 1 where returned id is one of the true k (id >= 0)."""
    eq = returned_ids[:, :, None] == true_ids[:, None, :]
    return (eq.any(axis=-1) & (returned_ids >= 0)).astype(jnp.float32)


def recall(returned_ids: jax.Array, true_ids: jax.Array) -> jax.Array:
    """Per-query recall [B]. An empty truth set (k == 0) scores 0,
    not nan — nothing was asked for, nothing was missed."""
    k = max(true_ids.shape[1], 1)
    return _membership(returned_ids, true_ids).sum(axis=1) / k


def average_precision(returned_ids: jax.Array,
                      true_ids: jax.Array) -> jax.Array:
    """Per-query AP [B] (paper's definition; empty truth scores 0)."""
    k = max(true_ids.shape[1], 1)
    rel = _membership(returned_ids, true_ids)  # [B, k]
    cum = jnp.cumsum(rel, axis=1)
    ranks = jnp.arange(1, k + 1, dtype=jnp.float32)[None, :]
    precision_at_r = cum / ranks
    return (precision_at_r * rel).sum(axis=1) / k


def relative_error(returned_d: jax.Array, true_d: jax.Array) -> jax.Array:
    """Per-query MRE [B], rank-paired; guards zero exact distances and
    unfilled (inf) answer slots — an ng answer with fewer than k
    candidates contributes only its filled ranks, as in the paper's
    incomplete-result-set discussion (§5)."""
    denom = jnp.maximum(true_d, 1e-12)
    re = (returned_d - true_d) / denom
    valid = (true_d > 1e-12) & jnp.isfinite(returned_d)
    k_eff = jnp.maximum(valid.sum(axis=1), 1)
    return jnp.where(valid, re, 0.0).sum(axis=1) / k_eff


def workload_metrics(
    returned_ids: jax.Array, returned_d: jax.Array,
    true_ids: jax.Array, true_d: jax.Array,
) -> Dict[str, float]:
    return {
        "avg_recall": float(recall(returned_ids, true_ids).mean()),
        "map": float(average_precision(returned_ids, true_ids).mean()),
        "mre": float(relative_error(returned_d, true_d).mean()),
    }
