"""THE refinement core: one loop body, parameterized by a LeafSource.

The paper's Algorithm 2 refinement loop used to exist twice — a
device ``lax.while_loop`` body in core/search.py and a hand-mirrored
host loop in store/ooc.py — with four jitted scoring steps mirroring
the (solo | cooperative) x (raw | pq) matrix on the out-of-core side.
This module is the single definition of every parity-critical piece:

  frontier    ``FrontierState`` + :func:`frontier_tick` /
              :func:`frontier_advance` — the lazy visit-order window
              (refill threshold = last consumed (lb, leaf-id) pair, so
              the emitted order IS the stable argsort order; proof in
              docs/PERF.md §2). The in-memory while_loop traces these
              functions inline; the out-of-core host loop calls the
              same functions jitted. Bit-exact visit-order parity holds
              by construction, not by mirroring.
  layout      :func:`candidate_layout` — [B, V] leaf window -> padded
              row positions + validity, identical in both residencies.
  dedup       :func:`dup_leaf_mask` / :func:`coop_mask` — the
              same-iteration duplicate-leaf mask that keeps the
              cooperative merges' distinct-id precondition.
  scoring     :func:`refine_step` — codec-dispatched score + select +
              merge. The four former ``_refine_step*`` variants are
              its (share, pq) corners; the in-memory branches are the
              same corners with the HBM data array as the gather pool.
  stopping    :func:`stop_mask` — Algorithm 2's predicates, written
              with operators only so the SAME function evaluates on
              device f32 tracers and host numpy f32 (IEEE-identical).

LeafSource protocol.  A source supplies residency: ``query_ctx``
builds the per-query scoring context (f32 queries + ids/norms, or PQ
ADC LUTs), ``gather(leaf, ok)`` makes a leaf window's rows reachable
on device (:class:`Gathered`: a gather pool + indices + validity),
``score`` folds them into the running top-k via :func:`refine_step`,
and ``finalize`` post-processes the final pool (identity everywhere
except the PQ exact re-rank). Implementations:

  ResidentSource          (here)       HBM-resident FrozenIndex; pure
                                       device gather, traced inside
                                       search_impl's while_loop.
  CachedStoreSource       (store/ooc)  memmap leaves through a
                                       DeviceLeafCache + prefetcher;
                                       host-driven gather.
  PQSource                (store/ooc)  uint8 codes ADC-scored on
                                       device + exact re-rank.

tests/test_refine.py runs the conformance suite against all three.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.kernels import ops

INF = jnp.float32(jnp.inf)


# ---------------------------------------------------------------- frontier
def default_frontier(num_leaves: int, visit_batch: int) -> int:
    """Default lazy-frontier width: a few refill-free batches of
    lookahead (covering this iteration's visits, the next_lb probe and
    the prefetch window) without approaching the full leaf count."""
    return min(num_leaves, max(64, 4 * visit_batch))


def frontier_select(lb_sq: jax.Array, thr_lb: jax.Array,
                    thr_id: jax.Array, f: int) -> tuple:
    """Partially select each lane's next ``f`` visit ranks: the
    lexicographic (lb, leaf-id) successors of the lane's threshold
    pair (thr = (-1, -1) selects the first window). lax.top_k breaks
    lb ties by lower leaf id — the stable argsort tie order — so
    chaining selections reproduces the full sorted visit order exactly
    (Algorithm 2's non-decreasing-lb condition; docs/PERF.md §2)."""
    L = lb_sq.shape[1]
    iota = jnp.arange(L, dtype=jnp.int32)
    remaining = jnp.where(
        (lb_sq > thr_lb[:, None])
        | ((lb_sq == thr_lb[:, None])
           & (iota[None, :] > thr_id[:, None])),
        lb_sq, INF)
    nv, ni = jax.lax.top_k(-remaining, f)
    return -nv, ni


class FrontierState(NamedTuple):
    """Per-lane lazy visit-order window (rank window) + refill
    threshold. Starts EMPTY (pos = F): the first :func:`frontier_tick`
    fills it from the (-1, -1) threshold, which selects ranks [0, F)."""
    lb: jax.Array      # [B, F] window lower bounds
    ids: jax.Array     # [B, F] window leaf ids
    pos: jax.Array     # [B] next unconsumed window position
    thr_lb: jax.Array  # [B] last consumed lb (refill threshold)
    thr_id: jax.Array  # [B] last consumed leaf id


def frontier_init(b: int, f: int) -> FrontierState:
    return FrontierState(
        lb=jnp.full((b, f), jnp.inf, jnp.float32),
        ids=jnp.zeros((b, f), jnp.int32),
        pos=jnp.full((b,), f, jnp.int32),
        thr_lb=jnp.full((b,), -1.0, jnp.float32),
        thr_id=jnp.full((b,), -1, jnp.int32),
    )


def frontier_window(st: FrontierState, offset: int, v: int) -> jax.Array:
    """[B, V] leaf ids at window positions pos+offset .. pos+offset+V-1
    (clamped to the window end; callers mask out-of-rank slots).
    offset=0 is this iteration's visit window; offset=d*V is the d-th
    speculative prefetch window."""
    f = st.lb.shape[1]
    ppos = jnp.minimum(
        st.pos[:, None] + offset + jnp.arange(v, dtype=jnp.int32)[None, :],
        f - 1)
    return jnp.take_along_axis(st.ids, ppos, axis=1)


def frontier_tick(st: FrontierState, lb_sq: jax.Array, active: jax.Array,
                  *, v: int, lookahead: int) -> tuple:
    """Refill lanes whose window no longer covers the next
    ``lookahead`` positions (amortized: once per floor(F/v) iterations
    per lane; skipped entirely via lax.cond when no lane needs it),
    then emit this iteration's [B, V] leaf window. Refilling selects
    the F lexicographic (lb, leaf-id) successors of the lane's
    threshold — exactly the next F ranks of the stable argsort order —
    so ANY width/lookahead yields the same visit order."""
    f = st.lb.shape[1]
    need = active & (st.pos > f - 1 - min(lookahead, f))

    def refill(args):
        lb, ids, pos = args
        nv, ni = frontier_select(lb_sq, st.thr_lb, st.thr_id, f)
        sel = need[:, None]
        return (jnp.where(sel, nv, lb), jnp.where(sel, ni, ids),
                jnp.where(need, 0, pos))

    lb, ids, pos = jax.lax.cond(
        jnp.any(need), refill, lambda a: a, (st.lb, st.ids, st.pos))
    st = st._replace(lb=lb, ids=ids, pos=pos)
    return st, frontier_window(st, 0, v)


def frontier_advance(st: FrontierState, active: jax.Array,
                     *, v: int) -> tuple:
    """Consume this iteration's v positions: peek the next unvisited
    lb (the stopping predicate's next_lb), move the refill threshold
    to the last consumed (lb, leaf-id) pair — the lexicographic
    successor selection point — and advance the window position.
    Inactive lanes keep their threshold (their windows are dead)."""
    f = st.lb.shape[1]
    peek = jnp.minimum(st.pos + v, f - 1)[:, None]
    next_lb = jnp.take_along_axis(st.lb, peek, axis=1)[:, 0]
    last = jnp.minimum(st.pos + v - 1, f - 1)[:, None]
    thr_lb = jnp.where(
        active, jnp.take_along_axis(st.lb, last, axis=1)[:, 0], st.thr_lb)
    thr_id = jnp.where(
        active, jnp.take_along_axis(st.ids, last, axis=1)[:, 0], st.thr_id)
    return st._replace(pos=st.pos + v, thr_lb=thr_lb,
                       thr_id=thr_id), next_lb


# ------------------------------------------------------------------ layout
def candidate_layout(offsets: jax.Array, leaf: jax.Array, ok: jax.Array,
                     max_leaf: int, clamp: int) -> tuple:
    """[B, V] leaf window + slot-usable mask -> ([B, V*M] padded row
    positions clamped to ``clamp``, [B, V*M] validity). A position is
    valid iff it lies inside its leaf's extent AND its slot is usable
    (in visit range, lane active). Invalid positions read a clamped
    (garbage) row that the scoring step masks to inf — identical
    arithmetic in both residencies."""
    b, v = leaf.shape
    start = offsets[leaf]
    end = offsets[leaf + 1]
    pos = jnp.arange(max_leaf, dtype=jnp.int32)[None, None, :]
    idx = start[:, :, None] + pos
    valid = (idx < end[:, :, None]) & ok[:, :, None]
    idx = jnp.minimum(idx, clamp)
    return idx.reshape(b, v * max_leaf), valid.reshape(b, v * max_leaf)


# ------------------------------------------------------------------- dedup
def dup_leaf_mask(leaf: jax.Array, ok: jax.Array) -> jax.Array:
    """[B, V] leaf ids + slot-usable mask -> [B, V] True where the slot
    repeats a leaf already pooled by an EARLIER usable slot this
    iteration. The cooperative paths mask those copies out before
    scoring, which (a) keeps ops.topk_merge_unique's distinct-id
    precondition and (b) changes nothing semantically — the copies
    carry bit-identical (d, id) pairs.

    dup[i] = exists j < i with leaf_j == leaf_i and ok[j]; computed in
    O(BV log BV): sort slots by (leaf, ok-first rank), find each leaf
    group's leader (its minimal-position usable slot), and a slot is a
    duplicate iff that leader is usable and strictly earlier."""
    bv = leaf.shape[0] * leaf.shape[1]
    fl = jnp.asarray(leaf, jnp.int32).reshape(bv)
    fo = jnp.asarray(ok).reshape(bv)
    posv = jnp.arange(bv, dtype=jnp.int32)
    rank = jnp.where(fo, posv, posv + bv)  # usable slots sort first
    leaf_s, _, pos_s, ok_s = jax.lax.sort(
        (fl, rank, posv, fo.astype(jnp.int32)), num_keys=2)
    t = jnp.arange(bv, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), leaf_s[1:] != leaf_s[:-1]])
    start_idx = jax.lax.cummax(jnp.where(is_start, t, 0))
    leader_ok = ok_s[start_idx] > 0
    leader_pos = pos_s[start_idx]
    dup_s = leader_ok & (leader_pos < pos_s)
    dup = jnp.zeros((bv,), bool).at[pos_s].set(dup_s)
    return dup.reshape(leaf.shape)


def coop_mask(leaf: jax.Array, ok: jax.Array,
              valid: jax.Array) -> jax.Array:
    """Cooperative-pool validity: ``valid`` [B, V*M] with
    same-iteration duplicate leaf copies masked out (the
    topk_merge_unique / coop_score_select distinct-id precondition).
    Per-lane visit accounting keeps using the unmasked ``valid``."""
    b, v = leaf.shape
    m = valid.shape[1] // v
    dup = dup_leaf_mask(leaf, ok)
    return valid & ~jnp.repeat(dup, m, axis=1, total_repeat_length=v * m)


# ----------------------------------------------------------------- scoring
class ScoreCtx(NamedTuple):
    """Per-query-batch scoring context (built once per search by
    ``LeafSource.query_ctx``)."""
    qf: jax.Array                  # [B, n] f32 queries
    ids: jax.Array                 # [npad] int32 global row ids
    norms: Optional[jax.Array]     # [npad] f32 squared row norms (raw)
    luts: Optional[jax.Array]      # [B, m, K] ADC tables (pq only)
    dead: Optional[jax.Array] = None  # [npad] bool row tombstones
    #   (docs/INGEST.md): True rows are superseded by the delta tier
    #   (deleted or re-inserted) and must never surface from this
    #   frozen unit. None = immutable store, zero masking cost.


class Gathered(NamedTuple):
    """One iteration's gatherable candidates. ``pool[gather_idx]``
    yields the ENCODED candidate rows; ``row_idx`` maps the same slots
    to padded row positions (ids / norms / re-rank reads)."""
    pool: jax.Array        # [P, cols] gather pool (HBM data or cache slots)
    gather_idx: jax.Array  # [B, V*M] int32 into pool
    row_idx: jax.Array     # [B, V*M] int32 padded row positions
    valid: jax.Array       # [B, V*M] bool


def refine_step(ctx: ScoreCtx, pool: jax.Array, gather_idx: jax.Array,
                row_idx: jax.Array, valid: jax.Array, top_d: jax.Array,
                top_i: jax.Array, *, share: bool, pq: bool,
                force_pallas: bool = False) -> tuple:
    """One refinement iteration's score + select + merge — THE loop
    body both residencies execute (in-memory traces it inside the
    while_loop; the host loop calls it jitted). (share, pq) dispatch:

      solo raw    gather [B, V*M] rows, fused L2 with cached norms,
                  O(k) topk_merge.
      coop raw    pool the iteration's rows, fused score+select per
                  lane (ops.coop_score_select — on TPU the [B, B*V*M]
                  distance matrix never reaches HBM), dedup merge.
      solo pq     ADC against each lane's LUT (one-hot MXU trick),
                  merge padded row POSITIONS (exact re-rank maps them
                  to ids).
      coop pq     fused ADC score+select per lane
                  (ops.pq_adc_select — on TPU the codes stream
                  through the one-hot MXU contraction tile by tile
                  and the [B, B*V*M] ADC distance matrix never
                  reaches HBM), dedup merge.

    For share=True the caller passes the coop_mask'ed validity (the
    distinct-id precondition); candidates are ids for raw codecs and
    padded row positions for pq — masked slots are -1 in both, which
    is the fused kernels' masking convention.

    Tombstones (ctx.dead, docs/INGEST.md) are folded into validity
    BEFORE candidates are formed: a dead row scores inf / candidate -1
    on every branch of the dispatch, identically in both residencies,
    so a deleted frozen row can never enter any running top-k."""
    k = top_d.shape[1]
    if ctx.dead is not None:
        valid = valid & ~ctx.dead[row_idx]
    if pq:
        cand = jnp.where(valid, row_idx, -1)
    else:
        cand = jnp.where(valid, ctx.ids[row_idx], -1)
    if share:
        flat = gather_idx.reshape(-1)
        rows = pool[flat]                          # [B*V*M, cols]
        candf = cand.reshape(-1)                   # lane-invariant
        if pq:
            sel_d, sel_i = ops.pq_adc_select(
                rows, ctx.luts, candf, min(2 * k, candf.shape[0]),
                force_pallas=force_pallas)
            return ops.dedup_merge_topk(sel_d, sel_i, top_d, top_i)
        sel_d, sel_i = ops.coop_score_select(
            ctx.qf, rows, ctx.norms[row_idx.reshape(-1)], candf,
            min(2 * k, candf.shape[0]), force_pallas=force_pallas)
        return ops.dedup_merge_topk(sel_d, sel_i, top_d, top_i)
    rows = pool[gather_idx]                        # [B, V*M, cols]
    if pq:
        d = ops.pq_adc_batch(rows, ctx.luts)
    else:
        d = ops.sq_l2(ctx.qf, rows, ctx.norms[row_idx])
    d = jnp.where(valid, d, INF)
    return ops.topk_merge(d, cand, top_d, top_i)


# ---------------------------------------------------------------- stopping
def stop_mask(next_lb, exhausted, bsf, eps_mult, rd_sq):
    """Algorithm 2's stopping predicates (squared-distance space):

        next_lb * (1+eps)^2 > bsf      [Alg.2 line 10/20 pruning]
      | bsf <= (1+eps)^2 * r_delta^2   [Alg.2 line 16 early stop]
      | exhausted                      [rank budget / scanned all]

    Operators only — evaluates identically on device f32 arrays and
    host numpy f32 (both IEEE-754), so the two loop drivers share this
    single definition. next_lb may be +inf (frontier pool exhausted);
    inf * eps_mult stays inf (eps_mult >= 1), never NaN."""
    return (next_lb * eps_mult > bsf) | (bsf <= eps_mult * rd_sq) \
        | exhausted


def leaf_lower_bounds(index, queries: jax.Array, *,
                      force_pallas: bool = False) -> jax.Array:
    """Filter stage: squared lower bound of every leaf for every lane
    ([B, L], the box_mindist kernel over the index's summaries) — the
    one pass whose output the lazy frontier partially selects."""
    q_sum = index.summarize_queries(queries)
    return ops.box_mindist(q_sum, index.box_lo, index.box_hi,
                           index.weights, force_pallas=force_pallas)


# -------------------------------------------------------------- LeafSource
@runtime_checkable
class LeafSource(Protocol):
    """Residency behind the refinement core. ``pq`` selects the
    scoring codec (ADC + re-rank vs fused L2); ``track_width`` is the
    per-lane candidate pool the loop carries (k, or rerank*k for pq);
    ``finalize`` maps the final pool to the reported top-k (identity,
    or the PQ exact re-rank) and returns any extra bytes read."""

    pq: bool

    def query_ctx(self, queries: jax.Array) -> ScoreCtx: ...

    def track_width(self, k: int) -> int: ...

    def gather(self, leaf, ok) -> Gathered: ...

    def score(self, ctx: ScoreCtx, g: Gathered, valid, top_d, top_i,
              *, share: bool) -> tuple: ...

    def finalize(self, ctx: ScoreCtx, top_d, top_i, k: int) -> tuple: ...


class ResidentSource:
    """LeafSource over an HBM-resident FrozenIndex. ``gather`` is pure
    device indexing, so the whole loop stays inside one
    lax.while_loop (search_impl traces these methods inline)."""

    pq = False

    def __init__(self, index, *, force_pallas: bool = False):
        self.index = index
        self.force_pallas = force_pallas
        self.norms = index.row_norms if index.row_norms is not None \
            else ops.row_sq_norms(index.data)

    def query_ctx(self, queries: jax.Array) -> ScoreCtx:
        return ScoreCtx(qf=queries.astype(jnp.float32),
                        ids=self.index.ids, norms=self.norms, luts=None)

    def track_width(self, k: int) -> int:
        return k

    def gather(self, leaf: jax.Array, ok: jax.Array) -> Gathered:
        idx, valid = candidate_layout(
            self.index.offsets, leaf, ok, self.index.max_leaf,
            self.index.data.shape[0] - 1)
        return Gathered(pool=self.index.data, gather_idx=idx,
                        row_idx=idx, valid=valid)

    def score(self, ctx, g, valid, top_d, top_i, *, share):
        return refine_step(ctx, g.pool, g.gather_idx, g.row_idx, valid,
                           top_d, top_i, share=share, pq=False,
                           force_pallas=self.force_pallas)

    def finalize(self, ctx, top_d, top_i, k):
        return top_d, top_i, 0
