"""Observability smoke for scripts/verify.sh: traced query over a tiny
spilled store, span tree vs counters BIT-EXACT.

The load-bearing assertion: the ``ooc.query`` span's ``bytes_read``
attribute — what the trace/QueryProfile reports — equals the
cache + prefetcher registry counters for the same query window
EXACTLY (no tolerance). The span attrs are set from the same typed
OocStats the counters feed, so a drift here means the schema plumbing
broke, not a flaky timer. Also checks: per-iteration gather spans sum
to the demand-read counter, the stop-condition attribution accounts
for every lane, tracing-disabled queries emit no spans, and the
chrome export round-trips.

    PYTHONPATH=src python scripts/obs_smoke.py
"""

import json
import os
import sys
import tempfile

import numpy as np

from repro import obs
from repro.core import guarantees as G
from repro.core import search as S
from repro.core.index import FrozenIndex
from repro.core.indexes import dstree
from repro.store import DeviceLeafCache, LeafPrefetcher


def main() -> int:
    rng = np.random.default_rng(7)
    data = np.cumsum(rng.normal(size=(512, 64)), axis=1)
    data = ((data - data.mean(1, keepdims=True))
            / (data.std(1, keepdims=True) + 1e-9)).astype(np.float32)
    queries = (data[rng.choice(512, 6, replace=False)]
               + 0.05 * rng.normal(size=(6, 64))).astype(np.float32)
    b = queries.shape[0]

    with tempfile.TemporaryDirectory() as tmp:
        idx = dstree.build(data, leaf_cap=32)
        store = FrozenIndex.load(idx.save(os.path.join(tmp, "idx")),
                                 resident="summaries")
        # small cache + real prefetcher: both demand and speculative
        # read paths feed the counters under test
        pf = LeafPrefetcher(store, depth=3)
        cache = DeviceLeafCache(store, capacity_leaves=8,
                                prefetcher=pf)
        try:
            # ---- tracing disabled: no spans, stats still complete
            obs.clear()
            out = S.search_ooc(store, queries, 5, G.epsilon(0.5),
                               cache=cache, prefetch_depth=2)
            assert not obs.tracer().spans(), "spans while disabled"
            assert out.stats.bytes_read > 0

            # ---- traced query over the SAME (now part-warm) cache
            cache.reset_counters()
            obs.enable()
            out = S.search_ooc(store, queries, 5, G.epsilon(0.5),
                               cache=cache, prefetch_depth=2)
            obs.disable()
        finally:
            pf.close()

        st = out.stats
        prof = obs.last_profile("ooc.query")
        assert prof is not None, "no ooc.query span collected"

        # THE assertion: span-tree bytes_read == cache+prefetcher
        # counters, bit-exact. Window counters (since reset) are what
        # OocStats snapshots; the rerank term is zero for a lossless
        # codec, so cache demand reads + prefetcher reads is the
        # whole byte population.
        counter_bytes = cache.bytes_read_sync + pf.bytes_read
        assert st.bytes_read_rerank == 0, st.bytes_read_rerank
        assert prof.attrs["bytes_read"] == counter_bytes, (
            prof.attrs["bytes_read"], counter_bytes)
        assert st.bytes_read == counter_bytes, (
            st.bytes_read, counter_bytes)

        # per-iteration gather spans: their demand-read bytes sum to
        # the cache's sync-read counter exactly
        gather_sync = sum(sp.attrs.get("bytes_read_sync", 0)
                          for sp in prof.spans)
        assert gather_sync == cache.bytes_read_sync, (
            gather_sync, cache.bytes_read_sync)

        # every lane stopped for exactly one attributed reason
        assert (st.stop_delta + st.stop_epsilon
                + st.stop_exhausted) == b
        assert prof.count("ooc.iteration") == st.iterations

        # the registry saw the same query (cumulative: >= window)
        reg_bytes = sum(
            c.value for c in obs.REGISTRY.collect(
                "store.cache.bytes_read_sync"))
        assert reg_bytes >= cache.bytes_read_sync

        # chrome export round-trips with the same span population
        trace_path = os.path.join(tmp, "trace.json")
        obs.dump_chrome_trace(trace_path)
        with open(trace_path) as f:
            doc = json.load(f)
        names = {e["name"] for e in doc["traceEvents"]}
        assert {"ooc.query", "ooc.filter", "ooc.iteration",
                "ooc.finalize"} <= names, names
        obs.clear()

    print("obs smoke OK: span tree bytes_read == cache+prefetcher "
          f"counters ({counter_bytes} bytes, {st.iterations} "
          f"iterations, stops d/e/x = {st.stop_delta}/"
          f"{st.stop_epsilon}/{st.stop_exhausted})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
