"""Serve-path smoke for scripts/verify.sh: Scheduler -> engine.query
over a tiny spilled store, plus the continuous-batching front.

Builds a small DistributedEngine, spills it (keep_resident=False so
every query MUST run the out-of-core path), pushes a mixed-deadline
request batch through the Scheduler retrieval front, and checks the
full-budget group's answers against brute force. Then drives the SAME
engine through the continuous front (serve/loop.ServeFront): mixed
deadlines submitted from the caller thread, lane workers answering
concurrently, every no-deadline (exact-tier) answer checked against
brute force, admission depth back to zero after drain. Fails loudly
if the deadline->guarantee mapping, the per-group engine dispatch,
the spilled-shard serving path, or the lane loop stops working.

Runs with span tracing ENABLED; when ``OBS_CHROME_TRACE`` is set the
collected spans are written there as Chrome trace-event JSON and
validated (the CI verify-fast job uploads the file as an artifact —
docs/OBSERVABILITY.md shows how to read it).

    PYTHONPATH=src python scripts/serve_smoke.py
    OBS_CHROME_TRACE=trace.json PYTHONPATH=src python scripts/serve_smoke.py
"""

import json
import os
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import IndexSpec, StoreSpec
from repro.core import search as S
from repro.core.engine import DistributedEngine
from repro.serve.batching import Request, Scheduler
from repro.serve.loop import ServeFront


def _continuous_section(eng, queries, truth):
    """Drive the continuous front over the already-built engine:
    mixed deadlines, answers via tickets, exact tier vs brute force."""
    deadlines = [None, 40.0, 8.0, None, 2.0, 40.0, None, 8.0]
    reqs = [Request(uid=100 + i, prompt=np.zeros(4, np.int32),
                    deadline_ms=deadlines[i], series=queries[i])
            for i in range(len(deadlines))]
    with ServeFront(eng, k=5, max_batch=4) as front:
        tickets = [front.submit(r) for r in reqs]
        outs = {t.uid: t.result(timeout=60.0) for t in tickets}
    assert sorted(outs) == [100 + i for i in range(len(reqs))], \
        "continuous front dropped requests"
    assert not any("error" in o for o in outs.values()), outs
    # no-deadline requests keep the exact tier no matter the queue
    # wait — their answers must equal brute force bit for bit
    for i, dl in enumerate(deadlines):
        if dl is None:
            assert outs[100 + i]["kind"] == "exact", outs[100 + i]
            assert np.array_equal(outs[100 + i]["ids"],
                                  np.asarray(truth.ids[i])), i
    # tight deadlines map to lower tiers (possibly lower than the
    # nominal tier — queue wait spends the budget)
    assert outs[104]["kind"] == "ng", outs[104]
    assert front.admission.depth == 0
    assert obs.REGISTRY.gauge("serve.queue_depth").value == 0
    acc = sum(c.value for c in obs.REGISTRY.collect(
        "serve.admission.accepted"))
    assert acc >= len(reqs), acc
    return outs


def main() -> int:
    rng = np.random.default_rng(0)
    data = np.cumsum(rng.normal(size=(512, 64)), axis=1)
    data = ((data - data.mean(1, keepdims=True))
            / (data.std(1, keepdims=True) + 1e-9)).astype(np.float32)
    queries = (data[rng.choice(512, 8, replace=False)]
               + 0.05 * rng.normal(size=(8, 64))).astype(np.float32)
    truth = S.brute_force(jnp.asarray(queries), jnp.asarray(data), 5)

    deadlines = [None, None, 40.0, 40.0, 12.0, 2.0, None, 12.0]

    obs.enable()
    try:
        with tempfile.TemporaryDirectory() as tmp:
            mesh = jax.make_mesh((1,), ("data",))
            eng = DistributedEngine(mesh, method="dstree").build(
                data, index=IndexSpec("dstree", leaf_cap=32),
                store=StoreSpec(spill_dir=os.path.join(tmp, "spill"),
                                codec="f32", keep_resident=False))
            # stamp the requests AFTER the (seconds-long) build:
            # guarantees map from the budget REMAINING at drain time,
            # so a request submitted before the build would drain with
            # its deadline already spent
            reqs = [Request(uid=i, prompt=np.zeros(4, np.int32),
                            deadline_ms=deadlines[i], series=queries[i])
                    for i in range(len(deadlines))]
            out = Scheduler().run_retrieval(eng, reqs, k=5)
            cont = _continuous_section(eng, queries, truth)
    finally:
        obs.disable()

    assert sorted(out) == list(range(len(reqs))), "requests dropped"
    kinds = {u: out[u]["kind"] for u in out}
    assert {kinds[0], kinds[2], kinds[5]} == \
        {"exact", "delta-epsilon", "ng"}, kinds
    # the full-budget (exact) group must match brute force exactly
    for u in (0, 1, 6):
        assert np.array_equal(out[u]["ids"],
                              np.asarray(truth.ids[u])), u
    # per-query stats ride the result entries (QueryResult.stats);
    # groups after the first may serve fully from the warm cache, so
    # the I/O accounting check is over the whole batch
    assert all(out[u]["stats"] is not None for u in out)
    assert sum(out[u]["stats"]["bytes_read"] for u in out) > 0
    # every retrieval group carries its own timed latency
    assert all(out[u]["retrieval_ms"] > 0 for u in out)

    # the trace the run just collected: one retrieval-group span per
    # guarantee group (groups are keyed by guarantee PARAMETERS, so
    # two deadlines can share kind "ng" yet form distinct groups),
    # each enclosing its engine/ooc span subtree
    trc = obs.tracer()
    grp_spans = trc.find("serve.retrieval_group")
    assert len(grp_spans) >= len(set(kinds.values())), \
        (len(grp_spans), kinds)
    assert {sp.attrs["kind"] for sp in grp_spans} == \
        set(kinds.values()), grp_spans
    trace_path = os.environ.get("OBS_CHROME_TRACE")
    if trace_path:
        obs.dump_chrome_trace(trace_path)
        with open(trace_path) as f:
            doc = json.load(f)
        evs = doc["traceEvents"]
        assert evs and all(e["ph"] == "X" and e["dur"] >= 0
                           for e in evs)
        assert {"serve.retrieval_group", "engine.query", "ooc.query"} \
            <= {e["name"] for e in evs}
        print(f"# chrome trace written to {trace_path} "
              f"({len(evs)} events)")
    obs.clear()
    print("serve smoke OK: scheduler -> engine.query over spilled "
          f"shards ({len(out)} requests, kinds: "
          f"{sorted(set(kinds.values()))}); continuous front answered "
          f"{len(cont)} requests across lanes "
          f"{sorted({o['kind'] for o in cont.values()})}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
