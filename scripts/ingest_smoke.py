"""Streaming-ingest smoke for scripts/verify.sh: the full write path
end to end — insert -> query -> delete -> compact -> query — over a
tiny spilled (out-of-core) engine, with the two properties the delta
tier promises (docs/INGEST.md) asserted loudly:

  Freshness.  Mutations go through the ServeFront write lane
  (serve/loop.submit_write); the ticket's ``applied_at`` stamp is the
  instant the rows became retrievable. The smoke measures
  submit -> applied_at -> first retrieving query and prints the lag
  (the same metric bench_serve_load.py snapshots into BENCH_pr10.json).

  Parity.  After every mutation batch, ``engine.query`` under the
  exact guarantee must be BIT-exact (ids and distances) against a
  from-scratch rebuild holding the same live rows — before AND after
  ``compact()`` re-freezes the memtable into an on-disk segment.

    PYTHONPATH=src python scripts/ingest_smoke.py
"""

import os
import sys
import tempfile

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import IndexSpec, StoreSpec
from repro.core import guarantees as G
from repro.core.engine import DistributedEngine
from repro.serve.loop import ServeFront

K = 5
N_BASE = 256
SERIES_LEN = 64


def _znorm(x):
    return ((x - x.mean(1, keepdims=True))
            / (x.std(1, keepdims=True) + 1e-9)).astype(np.float32)


def _oracle(live_rows, live_ids, queries, k, spill):
    """From-scratch rebuild over exactly the live rows, answers
    remapped to GLOBAL ids.

    ``live_ids`` must be ascending so the rebuild's array-order ids
    tie-break the same way as the engine's (distance, global id) rule.
    """
    assert np.all(np.diff(live_ids) > 0)
    oracle = DistributedEngine(mesh=None, shards=2).build(
        live_rows,
        index=IndexSpec("dstree", leaf_cap=32),
        store=StoreSpec(spill_dir=spill, codec="f32",
                        keep_resident=False))
    r = oracle.query(jnp.asarray(queries), k, G.exact())
    oracle.close()
    return np.asarray(r.dists), live_ids[np.asarray(r.ids)]


def _check_parity(eng, live_rows, live_ids, queries, tag, spill):
    od, oi = _oracle(live_rows, live_ids, queries, K, spill)
    out = eng.query(jnp.asarray(queries), K, G.exact())
    ids = np.asarray(out.ids)
    dists = np.asarray(out.dists)
    assert np.array_equal(ids, oi), \
        f"{tag}: ids diverge from rebuild oracle\n{ids}\nvs\n{oi}"
    assert np.allclose(dists, od, rtol=0.0, atol=0.0), \
        f"{tag}: distances diverge from rebuild oracle"
    return ids, dists


def main() -> int:
    rng = np.random.default_rng(7)
    base = _znorm(np.cumsum(rng.normal(size=(N_BASE, SERIES_LEN)),
                            axis=1))
    queries = _znorm(base[rng.choice(N_BASE, 6, replace=False)]
                     + 0.05 * rng.normal(size=(6, SERIES_LEN)))
    fresh_rows = _znorm(np.cumsum(
        rng.normal(size=(8, SERIES_LEN)), axis=1))

    obs.enable()
    try:
        with tempfile.TemporaryDirectory() as tmp:
            eng = DistributedEngine(mesh=None, shards=2).build(
                base,
                index=IndexSpec("dstree", leaf_cap=32),
                store=StoreSpec(spill_dir=os.path.join(tmp, "spill"),
                                codec="f32", keep_resident=False))

            # -- insert through the serve-front write lane ----------
            with ServeFront(eng, k=K, max_batch=4) as front:
                t_sub = obs.now()
                entry = front.submit_write(
                    "insert", rows=fresh_rows).result(timeout=60.0)
                new_ids = np.asarray(entry["ids"])
                applied_ms = (entry["applied_at"] - t_sub) * 1e3

                # freshness: the FIRST query after applied_at already
                # retrieves the new rows (query for an inserted series
                # verbatim -> its own id must be rank 1 at distance 0)
                probe = eng.query(
                    jnp.asarray(fresh_rows[:1]), 1, G.exact())
                t_seen = obs.now()
                assert int(np.asarray(probe.ids)[0, 0]) == new_ids[0], \
                    "inserted row not retrievable"
            freshness_ms = (t_seen - t_sub) * 1e3

            live_rows = np.concatenate([base, fresh_rows])
            live_ids = np.concatenate(
                [np.arange(N_BASE), new_ids]).astype(np.int64)
            _check_parity(eng, live_rows, live_ids, queries,
                          "post-insert",
                          os.path.join(tmp, "oracle1"))

            # -- delete: one frozen-base row that IS a top-1 answer,
            #    plus one of the fresh memtable rows ------------------
            top1 = int(np.asarray(
                eng.query(jnp.asarray(queries[:1]), 1,
                          G.exact()).ids)[0, 0])
            eng.delete([top1, int(new_ids[-1])])
            keep = ~np.isin(live_ids, [top1, int(new_ids[-1])])
            live_rows, live_ids = live_rows[keep], live_ids[keep]
            pre_ids, pre_d = _check_parity(
                eng, live_rows, live_ids, queries, "post-delete",
                os.path.join(tmp, "oracle2"))

            # -- compact: memtable -> on-disk segment; answers must
            #    not move by a single bit --------------------------
            assert eng.compact(), "compact() published no segment"
            post_ids, post_d = _check_parity(
                eng, live_rows, live_ids, queries, "post-compact",
                os.path.join(tmp, "oracle3"))
            assert np.array_equal(pre_ids, post_ids)
            assert np.array_equal(pre_d, post_d)

            ins = sum(c.value
                      for c in obs.REGISTRY.collect("delta.inserts"))
            cmp_n = sum(c.value for c in obs.REGISTRY.collect(
                "delta.compactions"))
            wr = sum(c.value
                     for c in obs.REGISTRY.collect("serve.writes"))
            assert ins == len(fresh_rows), ins
            assert cmp_n >= 1, cmp_n
            assert wr >= 1, wr
    finally:
        obs.disable()
        obs.clear()

    print("ingest smoke OK: insert -> query -> delete -> compact -> "
          f"query bit-exact vs rebuild; freshness "
          f"{freshness_ms:.1f} ms (applied in {applied_ms:.1f} ms)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
