#!/usr/bin/env bash
# Repo verification entry point.
#
#   scripts/verify.sh           # fast tier1 subset, then the full
#                               # tier-1 command (ROADMAP.md)
#   scripts/verify.sh fast      # tier1-marked subset only (~1-2 min:
#                               # kernels, summaries, metrics, search,
#                               # indexes, store)
#   scripts/verify.sh full      # the tier-1 command only
#   scripts/verify.sh chaos     # fault-tolerance smoke only (shard
#                               # kill -> degrade, owner kill ->
#                               # replica failover, docs/FAULT.md)
#
# The fast subset fails in minutes when a core-search/store regression
# slips in; model-smoke and distributed tests are marked `slow` and
# only run in the full pass (deselect with `-m "not slow"` manually).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

mode="${1:-all}"

# Store-format deprecation warnings are errors: the repo's own code and
# tests must never (re)generate or silently depend on pre-v2 artifacts
# (tests that exercise v1 read-compat catch the warning explicitly).
# Same precedent for the typed build/query surface (core/spec.py): the
# loose build(spill_dir=...)/search(delta=...) spellings are a
# one-release external shim; in-repo callers must use
# IndexSpec/StoreSpec + Guarantee (tests that exercise the shim catch
# the warning explicitly — docs/INGEST.md migration guide).
WFLAGS=(-W "error::repro.store.layout.StoreFormatDeprecationWarning"
        -W "error::repro.core.spec.APIDeprecationWarning")

run_fast() {
  echo "== verify: static analysis (repro.analysis, docs/ANALYSIS.md) =="
  python -m repro.analysis src/
  echo "== verify: fast tier1 subset =="
  python -m pytest -q -m tier1 "${WFLAGS[@]}"
  echo "== verify: bench snapshot smoke (compile-only, small scale) =="
  python -m benchmarks.run --snapshot --smoke
  echo "== verify: serve smoke (static Scheduler + continuous ServeFront, spilled store) =="
  python scripts/serve_smoke.py
  echo "== verify: obs smoke (span tree vs counters, bit-exact) =="
  python scripts/obs_smoke.py
  echo "== verify: ingest smoke (insert -> query -> delete -> compact -> query, freshness + parity) =="
  python scripts/ingest_smoke.py
  run_chaos
}

run_chaos() {
  echo "== verify: chaos smoke (shard kill -> degrade / failover) =="
  python scripts/chaos_smoke.py
}

run_full() {
  echo "== verify: full tier-1 command =="
  python -m pytest -x -q "${WFLAGS[@]}"
}

case "$mode" in
  fast) run_fast ;;
  full) run_full ;;
  chaos) run_chaos ;;
  all)  run_fast && run_full ;;
  *) echo "usage: scripts/verify.sh [fast|full|chaos|all]" >&2; exit 2 ;;
esac
