"""Chaos smoke for scripts/verify.sh: kill shards mid-serve, check
the answers stay honest (docs/FAULT.md).

Builds a 4-shard mesh-free spilled engine with replicas=2 and runs
the two acceptance scenarios end to end:

  degrade   one shard killed on EVERY copy, past the retry budget:
            the query must complete over the survivors, bit-exact to
            a brute-force oracle over the surviving rows, with
            OocStats reporting degraded/shards_lost and an
            effective_delta that EQUALS the histogram recomputation.
  failover  the same kill aimed only at the owner copy (attempt
            position 0): the query must return the FULL undegraded
            answer, bit-exact to the no-fault run, served from the
            byte-identical replica.

    PYTHONPATH=src python scripts/chaos_smoke.py
"""

import sys
import tempfile
import warnings

import jax.numpy as jnp
import numpy as np

from repro.core import IndexSpec, StoreSpec
from repro.core import search as S
from repro.core.engine import DistributedEngine
from repro.core.guarantees import Guarantee, effective_delta_after_loss
from repro.fault import FaultInjector
from repro.serve.fault import RetryPolicy

N, DIM, SHARDS, K = 1024, 64, 4, 5


def main() -> int:
    rng = np.random.default_rng(0)
    data = np.cumsum(rng.normal(size=(N, DIM)), axis=1)
    data = ((data - data.mean(1, keepdims=True))
            / (data.std(1, keepdims=True) + 1e-9)).astype(np.float32)
    queries = (data[rng.choice(N, 6, replace=False)]
               + 0.05 * rng.normal(size=(6, DIM))).astype(np.float32)
    qj = jnp.asarray(queries)
    retry = RetryPolicy(max_attempts=2, backoff_base_s=0.0)

    with tempfile.TemporaryDirectory() as tmp:
        eng = DistributedEngine(mesh=None, method="dstree",
                                shards=SHARDS)
        eng.build(data, index=IndexSpec("dstree", leaf_cap=32),
                  store=StoreSpec(spill_dir=tmp, codec="f32",
                                  keep_resident=False, replicas=2))
        clean = eng.query(qj, K, Guarantee())

        # ---- scenario 1: shard 1 lost past retries AND replicas
        inj = FaultInjector().kill_shard(1)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", UserWarning)
            res = eng.query(qj, K, Guarantee(),
                            ooc_opts={"fault": inj, "retry": retry})
        st = res.stats
        assert st.degraded and st.shards_lost == 1, st
        bounds = np.linspace(0, N, SHARDS + 1).astype(np.int64)
        mask = np.ones(N, bool)
        mask[bounds[1]:bounds[2]] = False
        ids_map = np.where(mask)[0]
        bf = S.brute_force(qj, jnp.asarray(data[mask]), K)
        assert np.array_equal(np.asarray(res.ids),
                              ids_map[np.asarray(bf.ids)]), \
            "degraded answer is not the surviving-shards fold"
        from repro.store import load_index
        hist = load_index(eng.shard_dirs[0],
                          resident="summaries").resident.hist
        want = effective_delta_after_loss(
            hist, np.asarray(res.dists[:, K - 1]),
            int((~mask).sum()), delta=1.0, epsilon=0.0)
        assert st.effective_delta == want, (st.effective_delta, want)

        # ---- scenario 2: owner copy killed, replica serves in full
        inj2 = FaultInjector().kill_shard(1, replica=0)
        res2 = eng.query(qj, K, Guarantee(),
                         ooc_opts={"fault": inj2, "retry": retry})
        st2 = res2.stats
        assert not st2.degraded and st2.failovers >= 1, st2
        assert np.array_equal(np.asarray(res2.ids),
                              np.asarray(clean.ids))
        assert np.array_equal(np.asarray(res2.dists),
                              np.asarray(clean.dists))
        eng.close()

    print("chaos smoke OK: shard kill degraded bit-exact "
          f"(effective_delta={st.effective_delta:.3g} over "
          f"{int((~mask).sum())} unseen rows); owner kill failed "
          "over to the replica with the full answer")
    return 0


if __name__ == "__main__":
    sys.exit(main())
